//! Sparse tensor IO: a compact binary format plus FROSTT-style text.
//!
//! Binary layout (little-endian):
//! ```text
//! magic  "FTNS"          4 bytes
//! version u32            currently 1
//! order   u32
//! dims    u64 × order
//! nnz     u64
//! indices u32 × nnz × order   (element-major)
//! values  f32 × nnz
//! ```
//!
//! Text format: one non-zero per line, `i_1 i_2 .. i_N value`, whitespace
//! separated; `#` comments; `one_based` toggles FROSTT's 1-based indices.
//!
//! Both readers are sized for the Dataset layer's "large files never
//! materialize twice" rule: the binary path bulk-reads straight into the
//! tensor's own element-major buffers (`CooTensor::from_parts`), and the
//! text path streams the file twice — a counting/inference scan, then a
//! push scan into an exactly-sized tensor — instead of collecting every
//! parsed line into an intermediate `Vec` first.

use super::bcsf::{BalanceStats, BcsfTensor, Task};
use super::coo::CooTensor;
use super::csf::CsfTensor;
use crate::util::bytes;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FTNS";
const VERSION: u32 = 1;
/// Magic of the internal B-CSF spill format (budgeted staging scratch —
/// never a public interchange format, so no version field).
const SPILL_MAGIC: &[u8; 4] = b"FTSP";

/// Write a COO tensor in the binary format.
pub fn write_binary(tensor: &CooTensor, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(tensor.order() as u32).to_le_bytes())?;
    for &d in tensor.dims() {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    w.write_all(&(tensor.nnz() as u64).to_le_bytes())?;
    bytes::write_u32s(&mut w, tensor.indices_flat())?;
    bytes::write_f32s(&mut w, tensor.values())?;
    w.flush()?;
    Ok(())
}

/// Read a binary tensor written by [`write_binary`].
pub fn read_binary(path: &Path) -> Result<CooTensor> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("truncated header")?;
    if &magic != MAGIC {
        bail!("bad magic: not a FTNS tensor file");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    let order = read_u32(&mut r)? as usize;
    if order == 0 || order > 64 {
        bail!("implausible order {order}");
    }
    let mut dims = Vec::with_capacity(order);
    for _ in 0..order {
        dims.push(read_u64(&mut r)? as usize);
    }
    let nnz = read_u64(&mut r)? as usize;
    // sanity-check the claimed nnz against the actual file size before
    // allocating (a hostile header must not drive a huge allocation)
    let file_len = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    let needed = (nnz as u64)
        .checked_mul(order as u64 * 4 + 4)
        .ok_or_else(|| anyhow::anyhow!("claimed nnz overflows"))?;
    if needed > file_len {
        bail!(
            "file too small for claimed nnz {} (needs {} bytes, file has {})",
            nnz,
            needed,
            file_len
        );
    }
    let mut indices = vec![0u32; nnz * order];
    bytes::read_u32s(&mut r, &mut indices).context("truncated file")?;
    let mut values = vec![0f32; nnz];
    bytes::read_f32s(&mut r, &mut values).context("truncated file")?;
    CooTensor::from_parts(dims, indices, values)
        .map_err(|e| anyhow::anyhow!("invalid tensor data: {e}"))
}

/// Write FROSTT-style text.
pub fn write_text(tensor: &CooTensor, path: &Path, one_based: bool) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let off = if one_based { 1 } else { 0 };
    writeln!(w, "# fastertucker tensor: dims {:?}", tensor.dims())?;
    for (coords, v) in tensor.iter() {
        for &c in coords {
            write!(w, "{} ", c + off)?;
        }
        writeln!(w, "{v}")?;
    }
    w.flush()?;
    Ok(())
}

/// Parse one text line into `coords` (cleared first). Returns the value, or
/// `None` for blank/comment lines. `lineno` is 0-based (messages are
/// 1-based, matching editors).
fn parse_text_line(
    line: &str,
    lineno: usize,
    off: i64,
    coords: &mut Vec<u32>,
) -> Result<Option<f32>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    coords.clear();
    // every token but the last is an index; the last is the value. Stream
    // the tokens with one of lookbehind instead of collecting them.
    let mut prev: Option<&str> = None;
    for tok in line.split_whitespace() {
        if let Some(p) = prev {
            let raw: i64 = p
                .parse()
                .with_context(|| format!("line {}: bad index '{}'", lineno + 1, p))?;
            let idx = raw - off;
            if idx < 0 {
                bail!("line {}: negative index after base adjustment", lineno + 1);
            }
            if idx > u32::MAX as i64 {
                bail!("line {}: index {} exceeds u32", lineno + 1, idx);
            }
            coords.push(idx as u32);
        }
        prev = Some(tok);
    }
    if coords.is_empty() {
        bail!("line {}: need at least one index and a value", lineno + 1);
    }
    let vtok = prev.expect("non-empty line has a last token");
    let v: f32 = vtok
        .parse()
        .with_context(|| format!("line {}: bad value '{}'", lineno + 1, vtok))?;
    Ok(Some(v))
}

/// First streaming pass over a text tensor: order consistency, inferred
/// dims (max index + 1 per mode) and the non-zero count — no element
/// storage.
fn scan_text(path: &Path, off: i64) -> Result<(Option<usize>, Vec<usize>, usize)> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let r = BufReader::new(f);
    let mut coords: Vec<u32> = Vec::new();
    let mut order: Option<usize> = None;
    let mut dims: Vec<usize> = Vec::new();
    let mut nnz = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if parse_text_line(&line, lineno, off, &mut coords)?.is_none() {
            continue;
        }
        match order {
            None => {
                order = Some(coords.len());
                dims = vec![0usize; coords.len()];
            }
            Some(o) if o != coords.len() => {
                bail!(
                    "line {}: inconsistent order {} vs {}",
                    lineno + 1,
                    coords.len(),
                    o
                )
            }
            _ => {}
        }
        for (k, &c) in coords.iter().enumerate() {
            dims[k] = dims[k].max(c as usize + 1);
        }
        nnz += 1;
    }
    Ok((order, dims, nnz))
}

/// Read FROSTT-style text; dims are inferred as max index + 1 unless given.
///
/// Two streaming passes: the internal `scan_text` sizes the allocation and infers the
/// shape, then the elements are pushed straight into the tensor — the file
/// contents are never buffered in an intermediate collection, so loading is
/// O(nnz) memory in exactly one copy.
pub fn read_text(
    path: &Path,
    dims: Option<Vec<usize>>,
    one_based: bool,
) -> Result<CooTensor> {
    let off: i64 = if one_based { 1 } else { 0 };
    let (order, inferred, nnz) = scan_text(path, off)?;
    let dims = match (dims, order) {
        (Some(d), Some(o)) => {
            if d.len() != o {
                bail!("given dims order {} != data order {}", d.len(), o);
            }
            d
        }
        (Some(d), None) => d,
        (None, Some(_)) => inferred.iter().map(|&d| d.max(1)).collect(),
        // empty file, no dims given: a degenerate 1-mode empty tensor,
        // matching the pre-streaming reader's behaviour
        (None, None) => vec![1],
    };
    let mut tensor = CooTensor::with_capacity(dims, nnz);
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let r = BufReader::new(f);
    let mut coords: Vec<u32> = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if let Some(v) = parse_text_line(&line, lineno, off, &mut coords)? {
            tensor.push_unchecked(&coords, v);
        }
    }
    tensor
        .validate()
        .map_err(|e| anyhow::anyhow!("invalid tensor data: {e}"))?;
    Ok(tensor)
}

/// Spill one built B-CSF rotation to `path` (little-endian, bit-exact:
/// reading it back reproduces every array byte for byte, which is what
/// keeps budget-capped staging bitwise-equal to unbounded staging).
pub(crate) fn write_bcsf_spill(t: &BcsfTensor, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create spill {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(SPILL_MAGIC)?;
    let n = t.order();
    write_u64(&mut w, n as u64)?;
    for &d in t.csf.dims() {
        write_u64(&mut w, d as u64)?;
    }
    for &m in &t.csf.mode_order {
        write_u64(&mut w, m as u64)?;
    }
    for l in 0..n {
        write_u64(&mut w, t.csf.level_idx[l].len() as u64)?;
        bytes::write_u32s(&mut w, &t.csf.level_idx[l])?;
    }
    for l in 0..n - 1 {
        write_u64(&mut w, t.csf.level_ptr[l].len() as u64)?;
        bytes::write_u32s(&mut w, &t.csf.level_ptr[l])?;
    }
    write_u64(&mut w, t.csf.values.len() as u64)?;
    bytes::write_f32s(&mut w, &t.csf.values)?;
    write_u64(&mut w, t.tasks.len() as u64)?;
    for task in &t.tasks {
        bytes::write_u32s(&mut w, &[task.fiber, task.start, task.end])?;
    }
    write_u64(&mut w, t.fiber_paths.len() as u64)?;
    bytes::write_u32s(&mut w, &t.fiber_paths)?;
    write_u64(&mut w, t.blocks.len() as u64)?;
    for &(lo, hi) in &t.blocks {
        bytes::write_u32s(&mut w, &[lo, hi])?;
    }
    write_u64(&mut w, t.block_sizes.len() as u64)?;
    bytes::write_u32s(&mut w, &t.block_sizes)?;
    write_u64(&mut w, t.fiber_threshold as u64)?;
    let s = &t.stats;
    for v in [
        s.num_fibers as u64,
        s.num_tasks as u64,
        s.num_blocks as u64,
        s.max_fiber_len as u64,
        s.max_block_nnz as u64,
        s.min_block_nnz as u64,
        s.mean_block_nnz.to_bits(),
        s.block_cv.to_bits(),
    ] {
        write_u64(&mut w, v)?;
    }
    w.flush()?;
    Ok(())
}

/// Read back a rotation spilled by [`write_bcsf_spill`].
pub(crate) fn read_bcsf_spill(path: &Path) -> Result<BcsfTensor> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open spill {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("truncated spill")?;
    if &magic != SPILL_MAGIC {
        bail!("bad magic: not a B-CSF spill file");
    }
    let n = read_u64(&mut r)? as usize;
    if n < 2 || n > 64 {
        bail!("implausible spill order {n}");
    }
    let mut dims = Vec::with_capacity(n);
    for _ in 0..n {
        dims.push(read_u64(&mut r)? as usize);
    }
    let mut mode_order = Vec::with_capacity(n);
    for _ in 0..n {
        mode_order.push(read_u64(&mut r)? as usize);
    }
    let read_u32_vec = |r: &mut BufReader<std::fs::File>| -> Result<Vec<u32>> {
        let len = read_u64(r)? as usize;
        let mut v = vec![0u32; len];
        bytes::read_u32s(r, &mut v).context("truncated spill")?;
        Ok(v)
    };
    let mut level_idx = Vec::with_capacity(n);
    for _ in 0..n {
        level_idx.push(read_u32_vec(&mut r)?);
    }
    let mut level_ptr = Vec::with_capacity(n - 1);
    for _ in 0..n - 1 {
        level_ptr.push(read_u32_vec(&mut r)?);
    }
    let vlen = read_u64(&mut r)? as usize;
    let mut values = vec![0f32; vlen];
    bytes::read_f32s(&mut r, &mut values).context("truncated spill")?;
    let csf = CsfTensor::from_raw_parts(dims, mode_order, level_idx, level_ptr, values);
    let ntasks = read_u64(&mut r)? as usize;
    let mut flat = vec![0u32; ntasks * 3];
    bytes::read_u32s(&mut r, &mut flat).context("truncated spill")?;
    let tasks = flat
        .chunks_exact(3)
        .map(|c| Task { fiber: c[0], start: c[1], end: c[2] })
        .collect();
    let fiber_paths = read_u32_vec(&mut r)?;
    let nblocks = read_u64(&mut r)? as usize;
    let mut flat = vec![0u32; nblocks * 2];
    bytes::read_u32s(&mut r, &mut flat).context("truncated spill")?;
    let blocks = flat.chunks_exact(2).map(|c| (c[0], c[1])).collect();
    let block_sizes = read_u32_vec(&mut r)?;
    let fiber_threshold = read_u64(&mut r)? as usize;
    let stats = BalanceStats {
        num_fibers: read_u64(&mut r)? as usize,
        num_tasks: read_u64(&mut r)? as usize,
        num_blocks: read_u64(&mut r)? as usize,
        max_fiber_len: read_u64(&mut r)? as usize,
        max_block_nnz: read_u64(&mut r)? as usize,
        min_block_nnz: read_u64(&mut r)? as usize,
        mean_block_nnz: f64::from_bits(read_u64(&mut r)?),
        block_cv: f64::from_bits(read_u64(&mut r)?),
    };
    Ok(BcsfTensor {
        csf,
        tasks,
        fiber_paths,
        blocks,
        block_sizes,
        fiber_threshold,
        stats,
    })
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("truncated file")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("truncated file")?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ft_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{}", std::process::id(), name))
    }

    fn random_tensor(seed: u64) -> CooTensor {
        let mut rng = Rng::new(seed);
        let mut t = CooTensor::new(vec![20, 30, 10]);
        for _ in 0..500 {
            let c = [
                rng.next_below(20) as u32,
                rng.next_below(30) as u32,
                rng.next_below(10) as u32,
            ];
            t.push(&c, rng.uniform_f32(-5.0, 5.0));
        }
        t
    }

    #[test]
    fn binary_roundtrip() {
        let t = random_tensor(1);
        let p = tmpfile("bin_roundtrip.ftns");
        write_binary(&t, &p).unwrap();
        let t2 = read_binary(&p).unwrap();
        assert_eq!(t.dims(), t2.dims());
        assert_eq!(t.canonical_elements(), t2.canonical_elements());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmpfile("bad_magic.ftns");
        std::fs::write(&p, b"NOPE00000000").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_rejects_truncation() {
        let t = random_tensor(2);
        let p = tmpfile("trunc.ftns");
        write_binary(&t, &p).unwrap();
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() / 2]).unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_rejects_out_of_bounds_index() {
        let t = random_tensor(8);
        let p = tmpfile("oob.ftns");
        write_binary(&t, &p).unwrap();
        // corrupt the first index to exceed dim 0 (=20)
        let mut data = std::fs::read(&p).unwrap();
        let header = 4 + 4 + 4 + 3 * 8 + 8;
        data[header..header + 4].copy_from_slice(&999u32.to_le_bytes());
        std::fs::write(&p, &data).unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_roundtrip_zero_based() {
        let t = random_tensor(3);
        let p = tmpfile("text0.tns");
        write_text(&t, &p, false).unwrap();
        let t2 = read_text(&p, Some(t.dims().to_vec()), false).unwrap();
        // text loses some float precision via decimal printing; compare coords
        let a = t.canonical_elements();
        let b = t2.canonical_elements();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.0, y.0);
            assert!((x.1 - y.1).abs() < 1e-4);
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_roundtrip_one_based() {
        let t = random_tensor(4);
        let p = tmpfile("text1.tns");
        write_text(&t, &p, true).unwrap();
        let t2 = read_text(&p, None, true).unwrap();
        assert_eq!(
            t.canonical_elements().len(),
            t2.canonical_elements().len()
        );
        // inferred dims must bound all indices
        for (c, _) in t2.iter() {
            for (k, &i) in c.iter().enumerate() {
                assert!((i as usize) < t2.dims()[k]);
            }
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_rejects_ragged_lines() {
        let p = tmpfile("ragged.tns");
        std::fs::write(&p, "1 2 3 1.0\n1 2 1.0\n").unwrap();
        assert!(read_text(&p, None, false).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_skips_comments_and_blank() {
        let p = tmpfile("comments.tns");
        std::fs::write(&p, "# header\n\n0 1 2.5\n").unwrap();
        let t = read_text(&p, None, false).unwrap();
        assert_eq!(t.nnz(), 1);
        assert_eq!(t.value(0), 2.5);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_rejects_index_outside_given_dims() {
        // the streaming reader validates bounds after the push pass — an
        // out-of-range index against caller-supplied dims must be an error,
        // not silent corruption
        let p = tmpfile("oob.tns");
        std::fs::write(&p, "0 1 1.0\n7 0 2.0\n").unwrap();
        assert!(read_text(&p, Some(vec![2, 2]), false).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn bcsf_spill_roundtrip_is_bit_exact() {
        let t = random_tensor(9);
        let b = BcsfTensor::build(&t, 1, 16, 64);
        let p = tmpfile("spill.bcsf");
        write_bcsf_spill(&b, &p).unwrap();
        let b2 = read_bcsf_spill(&p).unwrap();
        b2.validate().unwrap();
        assert_eq!(b.csf.dims(), b2.csf.dims());
        assert_eq!(b.csf.mode_order, b2.csf.mode_order);
        assert_eq!(b.csf.level_idx, b2.csf.level_idx);
        assert_eq!(b.csf.level_ptr, b2.csf.level_ptr);
        assert_eq!(b.csf.values.len(), b2.csf.values.len());
        for (x, y) in b.csf.values.iter().zip(b2.csf.values.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "values must survive bit-exact");
        }
        assert_eq!(b.tasks, b2.tasks);
        assert_eq!(b.fiber_paths, b2.fiber_paths);
        assert_eq!(b.blocks, b2.blocks);
        assert_eq!(b.block_sizes, b2.block_sizes);
        assert_eq!(b.fiber_threshold, b2.fiber_threshold);
        assert_eq!(b.stats.num_blocks, b2.stats.num_blocks);
        assert_eq!(
            b.stats.mean_block_nnz.to_bits(),
            b2.stats.mean_block_nnz.to_bits()
        );
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn spill_reader_rejects_garbage() {
        let p = tmpfile("spill_bad.bcsf");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(read_bcsf_spill(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_exact_allocation_no_double_materialization() {
        // the two-pass reader sizes the tensor exactly: nnz equals the data
        // line count even with interleaved comments/blanks
        let p = tmpfile("alloc.tns");
        let mut body = String::from("# c\n");
        for i in 0..100 {
            body.push_str(&format!("{} {} {}\n", i % 5, i % 7, i as f32 * 0.5));
            if i % 10 == 0 {
                body.push('\n');
            }
        }
        std::fs::write(&p, body).unwrap();
        let t = read_text(&p, None, false).unwrap();
        assert_eq!(t.nnz(), 100);
        assert_eq!(t.dims(), &[5, 7]);
        std::fs::remove_file(p).ok();
    }
}
