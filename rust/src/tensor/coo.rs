//! Coordinate-format sparse tensor, plus its epoch-engine adapter
//! ([`CooBlocks`]: the element stream cut into scheduler blocks).

use crate::algo::engine::{BlockSink, SparseStorage};
use crate::util::rng::Rng;

/// An N-order sparse tensor in coordinate format. Indices are stored
/// element-major (`indices[e*order + n]` is mode-n index of element `e`),
/// so one element's coordinates are a contiguous read — the access pattern
/// of the COO-based SGD loops.
#[derive(Clone, Debug)]
pub struct CooTensor {
    dims: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CooTensor {
    /// Empty tensor with the given mode sizes.
    pub fn new(dims: Vec<usize>) -> Self {
        assert!(!dims.is_empty(), "tensor needs at least one mode");
        assert!(
            dims.iter().all(|&d| d > 0 && d <= u32::MAX as usize),
            "mode sizes must fit u32"
        );
        CooTensor { dims, indices: Vec::new(), values: Vec::new() }
    }

    /// Empty tensor with buffers pre-sized for `nnz` elements.
    pub fn with_capacity(dims: Vec<usize>, nnz: usize) -> Self {
        let order = dims.len();
        let mut t = CooTensor::new(dims);
        t.indices.reserve(nnz * order);
        t.values.reserve(nnz);
        t
    }

    /// Number of modes N.
    #[inline]
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Mode sizes `I_1..I_N`.
    #[inline]
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Number of stored non-zeros |Ω|.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of cells that are stored.
    pub fn density(&self) -> f64 {
        let total: f64 = self.dims.iter().map(|&d| d as f64).product();
        self.nnz() as f64 / total
    }

    /// Coordinates of element `e`.
    #[inline]
    pub fn index(&self, e: usize) -> &[u32] {
        let n = self.order();
        &self.indices[e * n..(e + 1) * n]
    }

    /// Value of element `e`.
    #[inline]
    pub fn value(&self, e: usize) -> f32 {
        self.values[e]
    }

    /// All stored values, element-ordered.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// The element-major index buffer (`indices[e*order + n]`).
    pub fn indices_flat(&self) -> &[u32] {
        &self.indices
    }

    /// Append a non-zero. Panics in debug builds if out of bounds.
    pub fn push(&mut self, coords: &[u32], value: f32) {
        debug_assert_eq!(coords.len(), self.order());
        debug_assert!(coords
            .iter()
            .zip(self.dims.iter())
            .all(|(&c, &d)| (c as usize) < d));
        self.indices.extend_from_slice(coords);
        self.values.push(value);
    }

    /// Append without bounds checks — used by trusted loaders (`tensor::io`)
    /// which validate afterwards.
    pub(crate) fn push_unchecked(&mut self, coords: &[u32], value: f32) {
        self.indices.extend_from_slice(coords);
        self.values.push(value);
    }

    /// Assemble a tensor from element-major raw parts — the bulk-loader
    /// path (`tensor::io`). The parts are validated (shape, bounds, finite
    /// values) before the tensor is returned, so callers may fill the
    /// buffers with untrusted file contents.
    pub fn from_parts(
        dims: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<CooTensor, String> {
        if dims.is_empty() {
            return Err("tensor needs at least one mode".into());
        }
        if dims.iter().any(|&d| d == 0 || d > u32::MAX as usize) {
            return Err("mode sizes must be positive and fit u32".into());
        }
        let t = CooTensor { dims, indices, values };
        t.validate()?;
        Ok(t)
    }

    /// Iterate `(coords, value)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (&[u32], f32)> + '_ {
        (0..self.nnz()).map(move |e| (self.index(e), self.value(e)))
    }

    /// The staging shuffle every training path shares: a deterministic
    /// function of `seed` alone, so re-staging from the same `(train,
    /// seed)` reproduces the identical traversal order — the warm-start
    /// bitwise-resume guarantee (`tests/session_resume.rs`) depends on
    /// this being the single definition.
    pub fn training_shuffle(&self, seed: u64) -> CooTensor {
        let mut t = self.clone();
        t.shuffle(&mut Rng::new(seed ^ 0x5088));
        t
    }

    /// In-place Fisher–Yates shuffle of the element order (SGD sampling).
    pub fn shuffle(&mut self, rng: &mut Rng) {
        let n = self.order();
        for e in (1..self.nnz()).rev() {
            let j = rng.next_below(e + 1);
            if j != e {
                self.values.swap(e, j);
                for k in 0..n {
                    self.indices.swap(e * n + k, j * n + k);
                }
            }
        }
    }

    /// Stable sort of elements by the coordinate tuple permuted by
    /// `mode_order` (lexicographic). Returns the permutation applied
    /// (element ids in sorted order) without moving the stored data.
    pub fn sorted_perm(&self, mode_order: &[usize]) -> Vec<u32> {
        assert_eq!(mode_order.len(), self.order());
        let mut perm: Vec<u32> = (0..self.nnz() as u32).collect();
        let n = self.order();
        perm.sort_by(|&a, &b| {
            let ia = &self.indices[a as usize * n..a as usize * n + n];
            let ib = &self.indices[b as usize * n..b as usize * n + n];
            for &m in mode_order {
                match ia[m].cmp(&ib[m]) {
                    std::cmp::Ordering::Equal => continue,
                    other => return other,
                }
            }
            std::cmp::Ordering::Equal
        });
        perm
    }

    /// Check structural invariants (bounds, ragged arrays). Used by IO and
    /// property tests.
    pub fn validate(&self) -> Result<(), String> {
        if self.indices.len() != self.values.len() * self.order() {
            return Err(format!(
                "ragged storage: {} indices for {} values of order {}",
                self.indices.len(),
                self.values.len(),
                self.order()
            ));
        }
        for e in 0..self.nnz() {
            for (n, (&c, &d)) in
                self.index(e).iter().zip(self.dims.iter()).enumerate()
            {
                if c as usize >= d {
                    return Err(format!(
                        "element {e} mode {n}: index {c} out of bounds {d}"
                    ));
                }
            }
            if !self.value(e).is_finite() {
                return Err(format!("element {e}: non-finite value"));
            }
        }
        Ok(())
    }

    /// Collect elements as a sorted `(coords, value)` list — for equality
    /// testing across formats.
    pub fn canonical_elements(&self) -> Vec<(Vec<u32>, f32)> {
        let mut v: Vec<(Vec<u32>, f32)> =
            self.iter().map(|(c, x)| (c.to_vec(), x)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Approximate heap footprint of the stored elements (index + value
    /// buffers) — what a registry eviction of a derived structure frees.
    pub fn heap_bytes(&self) -> usize {
        self.indices.capacity() * std::mem::size_of::<u32>()
            + self.values.capacity() * std::mem::size_of::<f32>()
    }

    /// Split elements into two tensors by a boolean mask (true → first).
    pub fn partition(&self, mask: &[bool]) -> (CooTensor, CooTensor) {
        assert_eq!(mask.len(), self.nnz());
        let mut a = CooTensor::new(self.dims.clone());
        let mut b = CooTensor::new(self.dims.clone());
        for e in 0..self.nnz() {
            if mask[e] {
                a.push(self.index(e), self.value(e));
            } else {
                b.push(self.index(e), self.value(e));
            }
        }
        (a, b)
    }
}

/// Stack capacity for the per-element non-update coordinate tuple — large
/// enough for any realistic tensor order; higher orders take a one-off
/// heap buffer per block (cold path).
const COORD_STACK: usize = 32;

/// Schedulable COO block count for `nnz` elements at `block_nnz` apiece.
#[inline]
pub(crate) fn coo_num_blocks(nnz: usize, block_nnz: usize) -> usize {
    crate::util::ceil_div(nnz, block_nnz)
}

/// Non-zeros inside COO block `b` (all blocks are full except the last).
#[inline]
pub(crate) fn coo_block_weight(nnz: usize, block_nnz: usize, b: usize) -> usize {
    let lo = b * block_nnz;
    nnz.saturating_sub(lo).min(block_nnz)
}

/// Stream COO block `b` of the mode-`n` pass: every element is its own
/// chain group, delivered as a one-element leaf run. Shared by
/// [`CooBlocks`] and [`crate::tensor::prepared::PreparedStorage`], and
/// generic over the sink so the whole walk monomorphizes.
pub(crate) fn drive_coo_block<S: BlockSink>(
    coo: &CooTensor,
    block_nnz: usize,
    n: usize,
    b: usize,
    sink: &mut S,
) {
    let nnz = coo.nnz();
    let lo = b * block_nnz;
    let hi = (lo + block_nnz).min(nnz);
    let order = coo.order();
    let plen = order - 1;
    let idx = coo.indices_flat();
    let vals = coo.values();
    let mut stack = [0u32; COORD_STACK];
    let mut heap: Vec<u32> = Vec::new();
    let sub: &mut [u32] = if plen <= COORD_STACK {
        &mut stack[..plen]
    } else {
        heap.resize(plen, 0);
        &mut heap[..]
    };
    for e in lo..hi {
        let coords = &idx[e * order..(e + 1) * order];
        let mut k = 0;
        for (m, &c) in coords.iter().enumerate() {
            if m != n {
                sub[k] = c;
                k += 1;
            }
        }
        sink.group(sub);
        let leaf = e * order + n;
        sink.leaves(&idx[leaf..leaf + 1], &vals[e..e + 1]);
    }
}

/// Epoch-engine storage adapter: the COO element stream cut into blocks of
/// `block_nnz` elements (the unit a worker claims). Every element is its own
/// chain group — COO carries no fiber structure to share `v`/`w` across, so
/// the engine recomputes them per non-zero, exactly the COO algorithms'
/// cost model. The per-mode chain-mode lists are materialized once at
/// construction and borrowed per pass.
pub struct CooBlocks<'a> {
    coo: &'a CooTensor,
    block_nnz: usize,
    chain_modes: Vec<Vec<usize>>,
}

impl<'a> CooBlocks<'a> {
    /// Adapter cutting `coo` into blocks of `block_nnz` elements.
    pub fn new(coo: &'a CooTensor, block_nnz: usize) -> CooBlocks<'a> {
        let order = coo.order();
        let chain_modes = (0..order)
            .map(|n| (0..order).filter(|&m| m != n).collect())
            .collect();
        CooBlocks { coo, block_nnz: block_nnz.max(1), chain_modes }
    }
}

impl SparseStorage for CooBlocks<'_> {
    fn num_blocks(&self, _n: usize) -> usize {
        coo_num_blocks(self.coo.nnz(), self.block_nnz)
    }

    fn nnz(&self, _n: usize) -> usize {
        self.coo.nnz()
    }

    fn block_weight(&self, _n: usize, b: usize) -> usize {
        coo_block_weight(self.coo.nnz(), self.block_nnz, b)
    }

    fn chain_modes(&self, n: usize) -> &[usize] {
        &self.chain_modes[n]
    }

    fn drive_block<S: BlockSink>(&self, n: usize, b: usize, sink: &mut S) {
        drive_coo_block(self.coo, self.block_nnz, n, b, sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CooTensor {
        let mut t = CooTensor::new(vec![4, 3, 2]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[1, 2, 1], 2.0);
        t.push(&[3, 1, 0], 3.0);
        t
    }

    #[test]
    fn push_and_read_back() {
        let t = sample();
        assert_eq!(t.order(), 3);
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.index(1), &[1, 2, 1]);
        assert_eq!(t.value(2), 3.0);
    }

    #[test]
    fn density_computed() {
        let t = sample();
        assert!((t.density() - 3.0 / 24.0).abs() < 1e-12);
    }

    #[test]
    fn validate_accepts_good() {
        assert!(sample().validate().is_ok());
    }

    #[test]
    fn validate_rejects_nan() {
        let mut t = CooTensor::new(vec![2]);
        t.push(&[0], f32::NAN);
        assert!(t.validate().is_err());
    }

    #[test]
    fn shuffle_preserves_element_set() {
        let mut t = sample();
        let before = t.canonical_elements();
        let mut rng = Rng::new(3);
        t.shuffle(&mut rng);
        assert_eq!(before, t.canonical_elements());
    }

    #[test]
    fn training_shuffle_is_deterministic_per_seed() {
        let mut t = CooTensor::new(vec![100]);
        for i in 0..100u32 {
            t.push(&[i], i as f32);
        }
        let a = t.training_shuffle(9);
        let b = t.training_shuffle(9);
        assert_eq!(a.indices_flat(), b.indices_flat());
        assert_eq!(a.canonical_elements(), t.canonical_elements());
        let c = t.training_shuffle(10);
        assert_ne!(a.indices_flat(), c.indices_flat());
    }

    #[test]
    fn shuffle_changes_order_on_larger_tensor() {
        let mut t = CooTensor::new(vec![100]);
        for i in 0..100u32 {
            t.push(&[i], i as f32);
        }
        let mut rng = Rng::new(3);
        t.shuffle(&mut rng);
        let moved = (0..100).filter(|&e| t.index(e)[0] != e as u32).count();
        assert!(moved > 50);
    }

    #[test]
    fn sorted_perm_orders_lexicographically() {
        let t = sample();
        // sort by (mode2, mode0, mode1)
        let perm = t.sorted_perm(&[2, 0, 1]);
        let keys: Vec<Vec<u32>> = perm
            .iter()
            .map(|&e| {
                let idx = t.index(e as usize);
                vec![idx[2], idx[0], idx[1]]
            })
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn partition_splits_by_mask() {
        let t = sample();
        let (a, b) = t.partition(&[true, false, true]);
        assert_eq!(a.nnz(), 2);
        assert_eq!(b.nnz(), 1);
        assert_eq!(b.index(0), &[1, 2, 1]);
    }

    #[test]
    #[should_panic]
    fn new_rejects_empty_dims() {
        let _ = CooTensor::new(vec![]);
    }

    #[test]
    fn from_parts_validates_and_matches_push() {
        let pushed = sample();
        let bulk = CooTensor::from_parts(
            vec![4, 3, 2],
            pushed.indices_flat().to_vec(),
            pushed.values().to_vec(),
        )
        .unwrap();
        assert_eq!(bulk.canonical_elements(), pushed.canonical_elements());
        // ragged parts rejected
        assert!(CooTensor::from_parts(vec![4, 3, 2], vec![0, 0], vec![1.0]).is_err());
        // out-of-bounds index rejected
        assert!(
            CooTensor::from_parts(vec![2, 2], vec![0, 5], vec![1.0]).is_err()
        );
        // zero-sized mode rejected
        assert!(CooTensor::from_parts(vec![0], vec![], vec![]).is_err());
    }
}
