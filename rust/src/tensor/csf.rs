//! Compressed Sparse Fiber (CSF) storage.
//!
//! A CSF tensor is a prefix tree over the non-zeros for a fixed permutation
//! of the modes (`mode_order`). We always place the *update mode* last, so
//! the leaves of the tree are exactly the mode-n fibers the FasterTucker
//! algorithm shares its invariant intermediate `w = B^(n) v` across
//! (paper §III-B): every leaf run under one depth-(N-2) node holds all
//! non-zeros that agree on every index except mode n.
//!
//! Layout: `level_idx[l]` holds the coordinate of every node at depth `l`
//! (depth 0 = root level, depth N-1 = leaves, one entry per non-zero);
//! `level_ptr[l][k]..level_ptr[l][k+1]` is the children range of node `k`
//! of depth `l` within depth `l+1`. `values` aligns with the leaf level.

use super::coo::CooTensor;

/// CSF tensor with the leaf level on a chosen mode.
#[derive(Clone, Debug)]
pub struct CsfTensor {
    dims: Vec<usize>,
    /// Permutation of `0..N`; `mode_order[N-1]` is the leaf (update) mode.
    pub mode_order: Vec<usize>,
    /// Node coordinates per depth; `level_idx[N-1]` are leaf-mode indices.
    pub level_idx: Vec<Vec<u32>>,
    /// `level_ptr[l]` (for `l < N-1`) points into `level_idx[l+1]`.
    pub level_ptr: Vec<Vec<u32>>,
    /// Non-zero values, aligned with `level_idx[N-1]`.
    pub values: Vec<f32>,
}

impl CsfTensor {
    /// Build a CSF tree whose leaf level is `leaf_mode`. The internal modes
    /// are ordered by rotation `(leaf+1, leaf+2, .., leaf)` so that every
    /// rotation of the same tensor sorts deterministically.
    ///
    /// Duplicate coordinates in the input are merged by summation.
    pub fn build(coo: &CooTensor, leaf_mode: usize) -> CsfTensor {
        let n = coo.order();
        assert!(n >= 2, "CSF needs order >= 2");
        assert!(leaf_mode < n);
        let mode_order: Vec<usize> = (1..=n).map(|k| (leaf_mode + k) % n).collect();
        debug_assert_eq!(*mode_order.last().unwrap(), leaf_mode);
        Self::build_with_order(coo, mode_order)
    }

    /// Build with an explicit mode permutation (last entry = leaf mode).
    pub fn build_with_order(coo: &CooTensor, mode_order: Vec<usize>) -> CsfTensor {
        let n = coo.order();
        assert_eq!(mode_order.len(), n);
        {
            let mut seen = vec![false; n];
            for &m in &mode_order {
                assert!(m < n && !seen[m], "mode_order must be a permutation");
                seen[m] = true;
            }
        }
        let perm = coo.sorted_perm(&mode_order);

        let mut level_idx: Vec<Vec<u32>> = vec![Vec::new(); n];
        // level_ptr[l] starts with the implicit 0 and is closed at the end.
        let mut level_ptr: Vec<Vec<u32>> = vec![vec![0u32]; n.saturating_sub(1)];
        let mut values: Vec<f32> = Vec::with_capacity(coo.nnz());

        let mut prev_key: Vec<u32> = Vec::new();
        let mut key = vec![0u32; n];
        for &e in &perm {
            let idx = coo.index(e as usize);
            for (k, &m) in mode_order.iter().enumerate() {
                key[k] = idx[m];
            }
            let diff = if prev_key.is_empty() {
                0
            } else {
                match (0..n).find(|&k| prev_key[k] != key[k]) {
                    Some(d) => d,
                    None => {
                        // exact duplicate coordinate: merge by summation
                        *values.last_mut().unwrap() += coo.value(e as usize);
                        continue;
                    }
                }
            };
            for l in diff..n {
                // close the child pointer of the previous node at level l-1:
                // opening a node at level l means the node pushed at level
                // l-1 (this element or an earlier one) gains a child.
                level_idx[l].push(key[l]);
                if l > 0 {
                    // ensure ptr array of parent level has an open slot per
                    // parent node; handled at close below.
                }
            }
            // record child-start pointers: a new node at level l (l<n-1)
            // begins its children at the current end of level l+1 *minus*
            // the children just pushed for this element. Since for this
            // element levels diff..n-1 each receive exactly one new node and
            // one new child chain, the start of node-at-level-l's children
            // is len(level_idx[l+1]) - 1.
            for l in diff..n - 1 {
                let start = (level_idx[l + 1].len() - 1) as u32;
                level_ptr[l].push(start);
            }
            values.push(coo.value(e as usize));
            prev_key.clear();
            prev_key.extend_from_slice(&key);
        }
        // Close pointers: level_ptr[l] currently holds [0, start_1, start_2, ..]
        // where start_k is the first child of node k (k>=1). Append the total
        // child count as the final sentinel.
        for l in 0..n.saturating_sub(1) {
            let total = level_idx[l + 1].len() as u32;
            level_ptr[l].push(total);
            // The vector now has node_count + 2 entries ([0] + starts + [total])
            // but entry [0]=0 duplicates start of node 0 which was also pushed.
            // Fix: remove the extra leading zero added at init.
            level_ptr[l].remove(0);
            debug_assert_eq!(level_ptr[l].len(), level_idx[l].len() + 1);
        }
        CsfTensor {
            dims: coo.dims().to_vec(),
            mode_order,
            level_idx,
            level_ptr,
            values,
        }
    }

    /// Reassemble a tree from its raw arrays — the spill-file readback
    /// path (`tensor::io`). The caller is trusted to hand back arrays a
    /// prior build produced; `validate` still applies afterwards.
    pub(crate) fn from_raw_parts(
        dims: Vec<usize>,
        mode_order: Vec<usize>,
        level_idx: Vec<Vec<u32>>,
        level_ptr: Vec<Vec<u32>>,
        values: Vec<f32>,
    ) -> CsfTensor {
        CsfTensor { dims, mode_order, level_idx, level_ptr, values }
    }

    /// Number of modes N.
    #[inline]
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Mode sizes `I_1..I_N` (original order, not the CSF permutation).
    #[inline]
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Stored non-zeros (duplicates merged at build time).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Approximate heap footprint of the tree arrays (node coordinates,
    /// child pointers, values) — the dominant cost of a prepared rotation.
    pub fn heap_bytes(&self) -> usize {
        let idx: usize = self.level_idx.iter().map(|v| v.capacity() * 4).sum();
        let ptr: usize = self.level_ptr.iter().map(|v| v.capacity() * 4).sum();
        idx + ptr + self.values.capacity() * 4
    }

    /// The mode whose factor rows live at the leaves.
    #[inline]
    pub fn leaf_mode(&self) -> usize {
        *self.mode_order.last().unwrap()
    }

    /// Number of fibers (nodes at depth N-2).
    #[inline]
    pub fn num_fibers(&self) -> usize {
        let n = self.order();
        self.level_idx[n - 2].len()
    }

    /// Leaf range of fiber `f` within the leaf arrays.
    #[inline]
    pub fn fiber_range(&self, f: usize) -> (usize, usize) {
        let n = self.order();
        let ptr = &self.level_ptr[n - 2];
        (ptr[f] as usize, ptr[f + 1] as usize)
    }

    /// Leaf coordinates (mode `leaf_mode`) of fiber `f`.
    pub fn fiber_leaf_idx(&self, f: usize) -> &[u32] {
        let (s, e) = self.fiber_range(f);
        &self.level_idx[self.order() - 1][s..e]
    }

    /// Leaf values of fiber `f`.
    pub fn fiber_values(&self, f: usize) -> &[f32] {
        let (s, e) = self.fiber_range(f);
        &self.values[s..e]
    }

    /// Materialize, for every fiber, its path coordinates
    /// (`mode_order[0..N-1]` order): a `num_fibers × (N-1)` row-major table.
    /// The SGD loops index this instead of re-walking the tree.
    pub fn fiber_paths(&self) -> Vec<u32> {
        let n = self.order();
        let nf = self.num_fibers();
        let plen = n - 1;
        let mut paths = vec![0u32; nf * plen];
        // walk levels top-down, expanding each node's coordinate to the
        // fiber range it covers.
        // fiber span of node k at level l = [span_lo, span_hi) over fibers.
        // compute iteratively: spans at level n-2 are trivially [k, k+1).
        // For upper levels, children ranges compose.
        // Simpler: do a DFS with an explicit stack.
        if nf == 0 {
            return paths;
        }
        // stack entries: (level, node, path so far handled via coords buf)
        let mut coords = vec![0u32; plen];
        // child cursor per level
        let mut node_at = vec![0usize; plen];
        // iterative preorder using level_ptr
        fn dfs(
            t: &CsfTensor,
            level: usize,
            node: usize,
            coords: &mut [u32],
            paths: &mut [u32],
            plen: usize,
        ) {
            coords[level] = t.level_idx[level][node];
            if level == plen - 1 {
                let f = node;
                paths[f * plen..(f + 1) * plen].copy_from_slice(coords);
                return;
            }
            let (s, e) = (
                t.level_ptr[level][node] as usize,
                t.level_ptr[level][node + 1] as usize,
            );
            for child in s..e {
                dfs(t, level + 1, child, coords, paths, plen);
            }
        }
        let _ = &mut node_at;
        for root in 0..self.level_idx[0].len() {
            dfs(self, 0, root, &mut coords, &mut paths, plen);
        }
        paths
    }

    /// Reconstruct the COO element set (for round-trip tests / conversions).
    pub fn to_coo(&self) -> CooTensor {
        let n = self.order();
        let mut out = CooTensor::with_capacity(self.dims.clone(), self.nnz());
        let plen = n - 1;
        let paths = self.fiber_paths();
        let mut coords = vec![0u32; n];
        for f in 0..self.num_fibers() {
            let path = &paths[f * plen..(f + 1) * plen];
            for (k, &m) in self.mode_order[..plen].iter().enumerate() {
                coords[m] = path[k];
            }
            let leaf_mode = self.leaf_mode();
            let (s, e) = self.fiber_range(f);
            for leaf in s..e {
                coords[leaf_mode] = self.level_idx[n - 1][leaf];
                out.push(&coords, self.values[leaf]);
            }
        }
        out
    }

    /// Total tree node count (all levels) — storage metric reported by the
    /// format benchmarks.
    pub fn node_count(&self) -> usize {
        self.level_idx.iter().map(|v| v.len()).sum()
    }

    /// Structural invariants: monotone pointers, consistent level sizes,
    /// sorted sibling coordinates.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.order();
        if self.level_idx.len() != n {
            return Err("level_idx count != order".into());
        }
        if self.level_ptr.len() != n - 1 {
            return Err("level_ptr count != order-1".into());
        }
        if self.level_idx[n - 1].len() != self.values.len() {
            return Err("leaf/value length mismatch".into());
        }
        for l in 0..n - 1 {
            let ptr = &self.level_ptr[l];
            if ptr.len() != self.level_idx[l].len() + 1 {
                return Err(format!("level {l}: ptr length mismatch"));
            }
            if ptr[0] != 0 || *ptr.last().unwrap() as usize != self.level_idx[l + 1].len()
            {
                return Err(format!("level {l}: ptr endpoints wrong"));
            }
            for w in ptr.windows(2) {
                if w[0] > w[1] {
                    return Err(format!("level {l}: non-monotone ptr"));
                }
                if w[0] == w[1] {
                    return Err(format!("level {l}: empty internal node"));
                }
            }
            // siblings sorted strictly increasing
            for k in 0..self.level_idx[l].len() {
                let (s, e) = (ptr[k] as usize, ptr[k + 1] as usize);
                for w in self.level_idx[l + 1][s..e].windows(2) {
                    if w[0] >= w[1] {
                        return Err(format!("level {}: unsorted siblings", l + 1));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CooTensor {
        // 3-order, a few fibers along mode 2
        let mut t = CooTensor::new(vec![3, 3, 4]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[0, 0, 2], 2.0);
        t.push(&[0, 1, 1], 3.0);
        t.push(&[1, 0, 0], 4.0);
        t.push(&[1, 0, 3], 5.0);
        t.push(&[2, 2, 2], 6.0);
        t
    }

    #[test]
    fn build_preserves_nnz_and_dims() {
        let coo = sample();
        let csf = CsfTensor::build(&coo, 2);
        assert_eq!(csf.nnz(), 6);
        assert_eq!(csf.dims(), &[3, 3, 4]);
        assert_eq!(csf.leaf_mode(), 2);
        csf.validate().unwrap();
    }

    #[test]
    fn fiber_grouping_mode2() {
        let csf = CsfTensor::build(&sample(), 2);
        // fibers: (0,0)->[0,2], (0,1)->[1], (1,0)->[0,3], (2,2)->[2]
        assert_eq!(csf.num_fibers(), 4);
        let lens: Vec<usize> = (0..4)
            .map(|f| {
                let (s, e) = csf.fiber_range(f);
                e - s
            })
            .collect();
        assert_eq!(lens.iter().sum::<usize>(), 6);
        assert_eq!(*lens.iter().max().unwrap(), 2);
    }

    #[test]
    fn roundtrip_every_leaf_mode() {
        let coo = sample();
        for leaf in 0..3 {
            let csf = CsfTensor::build(&coo, leaf);
            csf.validate().unwrap();
            assert_eq!(
                coo.canonical_elements(),
                csf.to_coo().canonical_elements(),
                "leaf mode {leaf}"
            );
        }
    }

    #[test]
    fn fiber_paths_match_elements() {
        let coo = sample();
        let csf = CsfTensor::build(&coo, 0); // leaf mode 0, internal order [1,2]
        let plen = 2;
        let paths = csf.fiber_paths();
        assert_eq!(paths.len(), csf.num_fibers() * plen);
        // every (path, leaf) recombination must be an element of the input
        let elems = coo.canonical_elements();
        for f in 0..csf.num_fibers() {
            let path = &paths[f * plen..(f + 1) * plen];
            for (k, &leaf) in csf.fiber_leaf_idx(f).iter().enumerate() {
                let mut coords = vec![0u32; 3];
                coords[csf.mode_order[0]] = path[0];
                coords[csf.mode_order[1]] = path[1];
                coords[0] = leaf; // leaf mode 0
                let val = csf.fiber_values(f)[k];
                assert!(elems.contains(&(coords, val)));
            }
        }
    }

    #[test]
    fn duplicates_merge_by_sum() {
        let mut t = CooTensor::new(vec![2, 2]);
        t.push(&[1, 1], 1.5);
        t.push(&[1, 1], 2.5);
        t.push(&[0, 0], 1.0);
        let csf = CsfTensor::build(&t, 1);
        assert_eq!(csf.nnz(), 2);
        let elems = csf.to_coo().canonical_elements();
        assert_eq!(elems[1], (vec![1, 1], 4.0));
    }

    #[test]
    fn order2_matrix_supported() {
        let mut t = CooTensor::new(vec![3, 2]);
        t.push(&[0, 1], 1.0);
        t.push(&[2, 0], 2.0);
        t.push(&[2, 1], 3.0);
        let csf = CsfTensor::build(&t, 1);
        csf.validate().unwrap();
        assert_eq!(csf.num_fibers(), 2); // rows 0 and 2
        assert_eq!(t.canonical_elements(), csf.to_coo().canonical_elements());
    }

    #[test]
    fn empty_tensor() {
        let t = CooTensor::new(vec![4, 4, 4]);
        let csf = CsfTensor::build(&t, 1);
        assert_eq!(csf.nnz(), 0);
        assert_eq!(csf.num_fibers(), 0);
        csf.validate().unwrap();
    }

    #[test]
    fn node_count_reflects_sharing() {
        // two elements sharing a root prefix produce fewer nodes than two
        // elements with distinct prefixes
        let mut shared = CooTensor::new(vec![4, 4, 4]);
        shared.push(&[1, 1, 0], 1.0);
        shared.push(&[1, 1, 2], 1.0);
        let mut distinct = CooTensor::new(vec![4, 4, 4]);
        distinct.push(&[1, 1, 0], 1.0);
        distinct.push(&[2, 2, 2], 1.0);
        let cs = CsfTensor::build(&shared, 2);
        let cd = CsfTensor::build(&distinct, 2);
        assert!(cs.node_count() < cd.node_count());
    }
}
