//! Sparse tensor storage formats.
//!
//! Three formats, mirroring the paper's storage study (§IV-A, Table V):
//!
//! * [`coo::CooTensor`] — coordinate list, the format cuFastTucker and
//!   cuFasterTucker_COO iterate over.
//! * [`csf::CsfTensor`] — Compressed Sparse Fiber: a per-leaf-mode prefix
//!   tree over the non-zeros. All non-zeros of a mode-n *fiber* (all
//!   indices fixed except mode n) are contiguous leaves under one node,
//!   which is exactly the grouping FasterTucker's shared intermediate
//!   `w = B^(n) Q^(n)ᵀ s^(n)ᵀ` needs.
//! * [`bcsf::BcsfTensor`] — Balanced-CSF (Nisa et al., IPDPS'19): CSF plus
//!   (a) heavy fibers split into sub-fibers bounded by a threshold and
//!   (b) fibers packed into near-equal-nnz *blocks*, the unit a worker
//!   (GPU thread-group in the paper, scheduler task here) claims.
//!
//! [`prepared::PreparedStorage`] owns the once-built `(storage, chain)`
//! instantiation a `Session` streams its epochs over — the staging/sweep
//! separation the paper's Table V measures.

pub mod coo;
pub mod csf;
pub mod bcsf;
pub mod io;
pub mod prepared;
