//! Regenerators for every table and figure in the paper's evaluation (§V).
//!
//! Each function builds the workload, runs the algorithms, prints a
//! paper-shaped table/series, and persists JSON+CSV under `results/`.
//! Scales default to CPU-budget sizes and are overridable via environment
//! (`FT_NNZ`, `FT_EPOCHS`, `FT_J`, `FT_R`, …) so the same code can approach
//! paper scale on a bigger machine. Absolute numbers differ from the paper
//! (CPU vs RTX 3080Ti); the *shape* — who wins and by how much — is the
//! reproduction target (see EXPERIMENTS.md).

use crate::algo::Algo;
use crate::baselines::costmodel::{
    gta_verdict, parti_verdict, vest_verdict, Envelope, Workload,
};
use crate::config::TrainConfig;
use crate::coordinator::Session;
use crate::data::split::train_test;
use crate::data::synthetic::{self, RecommenderSpec};
use crate::tensor::coo::CooTensor;
use crate::util::json::Json;

use super::{env_scale, save_results, Table};

/// Common bench knobs, env-overridable.
#[derive(Clone, Debug)]
pub struct BenchScale {
    /// Non-zeros of the generated workload.
    pub nnz: usize,
    /// Epochs per measured run.
    pub epochs: usize,
    /// Factor rank J.
    pub j: usize,
    /// Core rank R.
    pub r: usize,
    /// Worker threads (0 = all cores).
    pub workers: usize,
}

impl BenchScale {
    /// Defaults overridable via `FT_NNZ`, `FT_EPOCHS`, `FT_J`, `FT_R`,
    /// `FT_WORKERS`.
    pub fn from_env() -> BenchScale {
        BenchScale {
            nnz: env_scale("FT_NNZ", 400_000),
            epochs: env_scale("FT_EPOCHS", 3),
            j: env_scale("FT_J", 32),
            r: env_scale("FT_R", 32),
            workers: env_scale("FT_WORKERS", 0),
        }
    }

    /// Reduced scale for smoke runs/tests.
    pub fn smoke() -> BenchScale {
        BenchScale { nnz: 20_000, epochs: 2, j: 8, r: 8, workers: 2 }
    }

    fn cfg(&self, t: &CooTensor) -> TrainConfig {
        TrainConfig {
            order: t.order(),
            dims: t.dims().to_vec(),
            j: self.j,
            r: self.r,
            lr_a: 1e-3,
            lr_b: 2e-5,
            workers: self.workers,
            ..TrainConfig::default()
        }
    }
}

fn dataset(name: &str, scale: &BenchScale) -> CooTensor {
    match name {
        "netflix-like" => {
            synthetic::recommender(&RecommenderSpec::netflix_like(scale.nnz), 90)
        }
        "yahoo-like" => {
            // Yahoo has ~2.5× Netflix's nnz in the paper; keep that ratio
            let spec = RecommenderSpec::yahoo_like(scale.nnz * 5 / 2);
            synthetic::recommender(&spec, 91)
        }
        other => panic!("unknown dataset {other}"),
    }
}

/// One algorithm's measured pass costs: mean per-iteration sweep seconds
/// plus the one-time staging cost, kept separate like the paper's Table V
/// (sessions build their storages once; staging never pollutes the sweep
/// numbers).
#[derive(Clone, Copy, Debug)]
struct PassCost {
    factor: f64,
    core: f64,
    prep: f64,
    /// Mean seconds per factor pass spent inside the `C^(n)` refresh hook
    /// (sampled from the session's `refresh_seconds` accumulator between
    /// passes, so the sweep/refresh split needs no second timer).
    factor_refresh: f64,
    /// Mean seconds per core pass spent inside the refresh hook.
    core_refresh: f64,
}

/// Measure mean factor/core pass seconds for one algorithm.
fn measure_passes(
    algo: Algo,
    cfg: TrainConfig,
    data: &CooTensor,
    epochs: usize,
) -> PassCost {
    let mut session = Session::new(algo, cfg, data).expect("session setup");
    let prep = session.prep_seconds();
    // warmup epoch excluded from the mean, as the paper averages iterations
    session.factor_pass();
    let mut fs = Vec::new();
    let mut cs = Vec::new();
    let mut frs = Vec::new();
    let mut crs = Vec::new();
    let mut mark = session.prep_stats().refresh_seconds;
    for _ in 0..epochs {
        fs.push(session.factor_pass());
        let now = session.prep_stats().refresh_seconds;
        frs.push(now - mark);
        mark = now;
        cs.push(session.core_pass());
        let now = session.prep_stats().refresh_seconds;
        crs.push(now - mark);
        mark = now;
    }
    assert_eq!(
        session.prep_stats().builds,
        1,
        "passes must sweep the cached storage, not restage it"
    );
    PassCost {
        factor: fs.iter().sum::<f64>() / fs.len() as f64,
        core: cs.iter().sum::<f64>() / cs.len() as f64,
        prep,
        factor_refresh: frs.iter().sum::<f64>() / frs.len() as f64,
        core_refresh: crs.iter().sum::<f64>() / crs.len() as f64,
    }
}

// --------------------------------------------------------------- Table V

/// Table V: single-iteration time + speedup over cuFastTucker for the
/// FastTucker family, `(Factor)` and `(Core)` modules, on both datasets.
pub fn table5(scale: &BenchScale) -> Table {
    let mut table = Table::new(
        "Table V — speedup over cuFastTucker (seconds per iteration, split \
         into one-time staging / per-pass C-refresh / per-pass sweep)",
        &[
            "Algorithm",
            "netflix staging",
            "netflix refresh",
            "netflix sweep",
            "speedup",
            "yahoo staging",
            "yahoo refresh",
            "yahoo sweep",
            "speedup",
        ],
    );
    let variants = [
        Algo::FastTucker,
        Algo::FasterTuckerCoo,
        Algo::FasterTuckerBcsf,
        Algo::FasterTucker,
    ];
    let mut results: Vec<Vec<PassCost>> = Vec::new(); // [dataset][algo]
    let datasets = ["netflix-like", "yahoo-like"];
    for name in datasets {
        let data = dataset(name, scale);
        let mut per_algo = Vec::new();
        for &algo in &variants {
            let cfg = scale.cfg(&data);
            per_algo.push(measure_passes(algo, cfg, &data, scale.epochs));
        }
        results.push(per_algo);
    }
    let mut json_rows = Vec::new();
    for module in ["Factor", "Core"] {
        let pick = |fc: PassCost| {
            if module == "Factor" {
                (fc.factor, fc.factor_refresh)
            } else {
                (fc.core, fc.core_refresh)
            }
        };
        let base: Vec<f64> =
            (0..datasets.len()).map(|d| pick(results[d][0]).0).collect();
        for (a, &algo) in variants.iter().enumerate() {
            let mut cells = vec![format!("{}({})", algo.name(), module)];
            let mut obj = vec![
                ("algorithm", Json::str(algo.name())),
                ("module", Json::str(module)),
            ];
            for d in 0..datasets.len() {
                let (secs, refresh) = pick(results[d][a]);
                // the refresh timer runs inside the pass wall clock, so the
                // three columns tile the measured iteration exactly
                let sweep = (secs - refresh).max(0.0);
                let speedup = base[d] / secs;
                cells.push(format!("{:.6}", results[d][a].prep));
                cells.push(format!("{refresh:.6}"));
                cells.push(format!("{sweep:.6}"));
                cells.push(if a == 0 {
                    "1.00X".into()
                } else {
                    format!("{speedup:.2}X")
                });
                obj.push((
                    if d == 0 { "netflix_seconds" } else { "yahoo_seconds" },
                    Json::num(secs),
                ));
                obj.push((
                    if d == 0 {
                        "netflix_refresh_seconds"
                    } else {
                        "yahoo_refresh_seconds"
                    },
                    Json::num(refresh),
                ));
                obj.push((
                    if d == 0 {
                        "netflix_sweep_seconds"
                    } else {
                        "yahoo_sweep_seconds"
                    },
                    Json::num(sweep),
                ));
                obj.push((
                    if d == 0 { "netflix_speedup" } else { "yahoo_speedup" },
                    Json::num(speedup),
                ));
                // staging cost: identical for both modules, so emit it only
                // on the Factor rows to avoid double-counting in aggregates
                if module == "Factor" {
                    obj.push((
                        if d == 0 {
                            "netflix_prep_seconds"
                        } else {
                            "yahoo_prep_seconds"
                        },
                        Json::num(results[d][a].prep),
                    ));
                }
            }
            table.row(cells);
            json_rows.push(Json::obj(obj));
        }
    }
    save_results("table5", &Json::Arr(json_rows), Some(&table.to_csv()));
    table
}

// --------------------------------------------------------------- Table IV

/// Table IV: sparse Tucker baselines — measured rows for our implemented
/// P-Tucker / SGD-Tucker-class / cuTucker, cost-model verdicts (labelled
/// `estimated`) for Vest / ParTi / GTA at the PAPER's dataset sizes.
pub fn table4(scale: &BenchScale) -> Table {
    let mut table = Table::new(
        "Table IV — sparse Tucker baselines (seconds per iteration)",
        &["Algorithm", "netflix-like", "yahoo-like"],
    );
    // full-core baselines blow up as J^N = 32^3 per non-zero: measure at the
    // paper's J=32 but on a reduced nnz slice for tractability (the gap per
    // non-zero is what Table IV demonstrates; it is nnz-independent).
    let bj = env_scale("FT_BASELINE_J", 32).min(scale.j);
    let bnnz = env_scale("FT_BASELINE_NNZ", (scale.nnz / 8).max(1000));
    let bscale = BenchScale { nnz: bnnz, j: bj, r: bj, ..scale.clone() };

    // measured seconds per dataset for each implemented baseline
    let mut ptucker_f = Vec::new();
    let mut cutucker_f = Vec::new();
    let mut cutucker_c = Vec::new();
    let mut fastucker_f = Vec::new();
    for name in ["netflix-like", "yahoo-like"] {
        let data = dataset(name, &bscale);
        let reps = 1.max(bscale.epochs / 2);
        let pt = measure_passes(Algo::PTucker, bscale.cfg(&data), &data, reps);
        ptucker_f.push(pt.factor);
        let cu = measure_passes(Algo::CuTucker, bscale.cfg(&data), &data, reps);
        cutucker_f.push(cu.factor);
        cutucker_c.push(cu.core);
        let ft = measure_passes(Algo::FastTucker, bscale.cfg(&data), &data, 1);
        fastucker_f.push(ft.factor);
    }
    let rows: Vec<(String, Vec<f64>)> = vec![
        (format!("P-Tucker(Factor) [J={bj}]"), ptucker_f),
        (format!("cuTucker(Factor) [J={bj}]"), cutucker_f),
        (format!("cuTucker(Core) [J={bj}]"), cutucker_c),
        (format!("cuFastTucker(Factor) [J={bj}] (reference)"), fastucker_f),
    ];
    let mut json_rows = Vec::new();
    for (name, secs) in &rows {
        table.row(vec![
            name.clone(),
            format!("{:.4}", secs[0]),
            format!("{:.4}", secs[1]),
        ]);
        json_rows.push(Json::obj(vec![
            ("algorithm", Json::str(name.clone())),
            ("netflix_seconds", Json::num(secs[0])),
            ("yahoo_seconds", Json::num(secs[1])),
            ("estimated", Json::Bool(false)),
        ]));
    }
    // cost-model verdicts at PAPER scale (J=32), calibrated to this machine
    let env = Envelope { flops: calibrate_flops(), ..Envelope::default() };
    let paper_netflix = Workload {
        order: 3,
        dims: vec![480_189, 17_770, 2_182],
        nnz: 99_072_112,
        j: 32,
    };
    let paper_yahoo = Workload {
        order: 3,
        dims: vec![1_000_990, 624_961, 3_075],
        nnz: 250_272_286,
        j: 32,
    };
    for (name, f) in [
        ("Vest(Factor) @paper-scale", vest_verdict as fn(&Workload, &Envelope) -> _),
        ("ParTi(Factor) @paper-scale", parti_verdict),
        ("GTA(Factor) @paper-scale", gta_verdict),
    ] {
        let vn = f(&paper_netflix, &env);
        let vy = f(&paper_yahoo, &env);
        table.row(vec![name.to_string(), vn.render(), vy.render()]);
        json_rows.push(Json::obj(vec![
            ("algorithm", Json::str(name)),
            ("netflix", vn.to_json()),
            ("yahoo", vy.to_json()),
            ("estimated", Json::Bool(true)),
        ]));
    }
    save_results("table4", &Json::Arr(json_rows), Some(&table.to_csv()));
    table
}

/// Measure this machine's sustained f32 FMA throughput for the cost model.
fn calibrate_flops() -> f64 {
    let n = 1 << 20;
    let a: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
    let b: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
    let t = std::time::Instant::now();
    let mut acc = 0.0f32;
    let reps = 8;
    for _ in 0..reps {
        for i in 0..n {
            acc += a[i] * b[i];
        }
    }
    let secs = t.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    (2.0 * reps as f64 * n as f64 / secs).max(1e9)
}

// --------------------------------------------------------------- Fig. 2/3

/// Fig. 2/3: RMSE & MAE convergence over epochs for all variants, both
/// datasets. Returns (table of final metrics, per-algo CSV series saved).
pub fn fig3(scale: &BenchScale) -> Table {
    let mut table = Table::new(
        "Fig. 3 — convergence (final test RMSE / MAE after training)",
        &["Algorithm", "dataset", "final RMSE", "final MAE", "mean s/iter"],
    );
    let epochs = env_scale("FT_FIG3_EPOCHS", 10.max(scale.epochs));
    let mut json = Vec::new();
    for name in ["netflix-like", "yahoo-like"] {
        let data = dataset(name, scale);
        let (train, test) = train_test(&data, 0.1, 17);
        let test = crate::data::split::filter_cold(&test, &train);
        for algo in [
            Algo::FastTucker,
            Algo::FasterTuckerCoo,
            Algo::FasterTuckerBcsf,
            Algo::FasterTucker,
        ] {
            let cfg = scale.cfg(&train);
            let mut session = Session::new(algo, cfg, &train).expect("session");
            let report = session.run(epochs, Some(&test));
            let series_name =
                format!("fig3_{}_{}", name.replace('-', "_"), algo.name().replace('-', "_"));
            save_results(
                &series_name,
                &report.convergence.to_json(),
                Some(&report.convergence.to_csv()),
            );
            table.row(vec![
                algo.name().to_string(),
                name.to_string(),
                format!("{:.4}", report.convergence.last_rmse()),
                format!("{:.4}", report.convergence.last_mae()),
                format!("{:.4}", report.mean_epoch_seconds()),
            ]);
            json.push(Json::obj(vec![
                ("algorithm", Json::str(algo.name())),
                ("dataset", Json::str(name)),
                ("rmse", Json::num(report.convergence.last_rmse())),
                ("mae", Json::num(report.convergence.last_mae())),
                ("series", Json::str(series_name)),
            ]));
        }
    }
    save_results("fig3_summary", &Json::Arr(json), Some(&table.to_csv()));
    table
}

// --------------------------------------------------------------- Fig. 4(a)

/// Fig. 4(a): single-iteration time vs tensor order (3..max_order), fixed
/// dim and nnz — FasterTucker's flat growth vs FastTucker's linear-in-N
/// blow-up.
pub fn fig4a(scale: &BenchScale) -> Table {
    let mut table = Table::new(
        "Fig. 4(a) — single-iteration seconds vs order",
        &["order", "cuFastTucker", "cuFasterTucker_COO", "cuFasterTucker"],
    );
    let max_order = env_scale("FT_MAX_ORDER", 8);
    let dim = env_scale("FT_ORDER_DIM", 1_000);
    let nnz = env_scale("FT_ORDER_NNZ", scale.nnz / 2);
    let mut json = Vec::new();
    for order in 3..=max_order {
        let data = synthetic::order_sweep(order, dim, nnz, 70 + order as u64);
        let mut cells = vec![format!("{order}")];
        let mut obj = vec![("order", Json::num(order as f64))];
        for algo in [Algo::FastTucker, Algo::FasterTuckerCoo, Algo::FasterTucker] {
            let cfg = scale.cfg(&data);
            let cost = measure_passes(algo, cfg, &data, 1);
            let total = cost.factor + cost.core;
            cells.push(format!("{total:.4}"));
            obj.push((algo.name(), Json::num(total)));
        }
        table.row(cells);
        json.push(Json::obj(obj));
    }
    save_results("fig4a", &Json::Arr(json), Some(&table.to_csv()));
    table
}

// --------------------------------------------------------------- Fig. 4(b,c)

/// Fig. 4(b,c): non-zeros processed per second vs sparsity, for the factor
/// module (b) and the core module (c).
pub fn fig4bc(scale: &BenchScale) -> Table {
    let mut table = Table::new(
        "Fig. 4(b,c) — nnz/s vs sparsity (factor | core)",
        &[
            "sparsity",
            "nnz",
            "FastTucker factor",
            "FasterTucker factor",
            "FastTucker core",
            "FasterTucker core",
        ],
    );
    let dim = env_scale("FT_SPARSITY_DIM", 300);
    let cells_total = dim * dim * dim;
    let mut json = Vec::new();
    for pct in [2usize, 4, 6, 8, 10] {
        let nnz = cells_total * pct / 100;
        let data = synthetic::sparsity_sweep(dim, nnz, 80 + pct as u64);
        let mut row = vec![format!("{pct}%"), format!("{nnz}")];
        let mut obj = vec![
            ("sparsity_pct", Json::num(pct as f64)),
            ("nnz", Json::num(nnz as f64)),
        ];
        let mut factor_tps = Vec::new();
        let mut core_tps = Vec::new();
        for algo in [Algo::FastTucker, Algo::FasterTucker] {
            let cfg = scale.cfg(&data);
            let cost = measure_passes(algo, cfg, &data, 1);
            let (f, c) = (cost.factor, cost.core);
            factor_tps.push(nnz as f64 / f);
            core_tps.push(nnz as f64 / c);
            obj.push((
                match algo {
                    Algo::FastTucker => "fastucker_factor_nnz_per_s",
                    _ => "fastertucker_factor_nnz_per_s",
                },
                Json::num(nnz as f64 / f),
            ));
            obj.push((
                match algo {
                    Algo::FastTucker => "fastucker_core_nnz_per_s",
                    _ => "fastertucker_core_nnz_per_s",
                },
                Json::num(nnz as f64 / c),
            ));
        }
        for t in factor_tps.iter().chain(core_tps.iter()) {
            row.push(format!("{:.3e}", t));
        }
        table.row(row);
        json.push(Json::obj(obj));
    }
    save_results("fig4bc", &Json::Arr(json), Some(&table.to_csv()));
    table
}

// --------------------------------------------------------------- Ablations

/// Ablation: B-CSF fiber-split threshold (paper §V-A fixes 128 as "best").
/// Sweeps the threshold and reports factor-pass time + balance stats.
pub fn ablation_threshold(scale: &BenchScale) -> Table {
    let mut table = Table::new(
        "Ablation — B-CSF fiber threshold (factor pass seconds, balance)",
        &["threshold", "s/iter", "tasks", "max block nnz", "block cv", "worker imbalance"],
    );
    let data = dataset("netflix-like", scale);
    let mut json = Vec::new();
    for threshold in [8usize, 32, 128, 512, usize::MAX >> 1] {
        let mut cfg = scale.cfg(&data);
        cfg.fiber_threshold = threshold;
        let mut session =
            Session::new(Algo::FasterTucker, cfg, &data).expect("session");
        session.factor_pass(); // warmup
        let mut secs = Vec::new();
        for _ in 0..scale.epochs.max(1) {
            secs.push(session.factor_pass());
        }
        let mean = secs.iter().sum::<f64>() / secs.len() as f64;
        // measured per-worker scheduling balance of the last pass — the
        // number the paper's §IV-B load-balance argument is about
        let imbalance = session
            .factor_worker_stats()
            .expect("engine pass records worker stats")
            .imbalance();
        let stats = &session.balance_stats().unwrap()[0];
        let label = if threshold > 1 << 30 {
            "unbounded".to_string()
        } else {
            threshold.to_string()
        };
        table.row(vec![
            label.clone(),
            format!("{mean:.4}"),
            format!("{}", stats.num_tasks),
            format!("{}", stats.max_block_nnz),
            format!("{:.3}", stats.block_cv),
            format!("{imbalance:.3}"),
        ]);
        json.push(Json::obj(vec![
            ("threshold", Json::str(label)),
            ("seconds", Json::num(mean)),
            ("tasks", Json::num(stats.num_tasks as f64)),
            ("max_block_nnz", Json::num(stats.max_block_nnz as f64)),
            ("block_cv", Json::num(stats.block_cv)),
            ("worker_imbalance", Json::num(imbalance)),
        ]));
    }
    save_results("ablation_threshold", &Json::Arr(json), Some(&table.to_csv()));
    table
}

/// Ablation: scheduler block size (work granularity the paper fixes via
/// thread-block shape). Too small → scheduling overhead; too large → load
/// imbalance across workers.
pub fn ablation_block_size(scale: &BenchScale) -> Table {
    let mut table = Table::new(
        "Ablation — scheduler block size (factor pass seconds)",
        &["block nnz", "s/iter", "blocks"],
    );
    let data = dataset("netflix-like", scale);
    let mut json = Vec::new();
    for block in [512usize, 2048, 8192, 32768, 131072] {
        let mut cfg = scale.cfg(&data);
        cfg.block_nnz = block;
        let mut session =
            Session::new(Algo::FasterTucker, cfg, &data).expect("session");
        session.factor_pass();
        let mut secs = Vec::new();
        for _ in 0..scale.epochs.max(1) {
            secs.push(session.factor_pass());
        }
        let mean = secs.iter().sum::<f64>() / secs.len() as f64;
        let blocks = session.balance_stats().unwrap()[0].num_blocks;
        table.row(vec![
            block.to_string(),
            format!("{mean:.4}"),
            blocks.to_string(),
        ]);
        json.push(Json::obj(vec![
            ("block_nnz", Json::num(block as f64)),
            ("seconds", Json::num(mean)),
            ("blocks", Json::num(blocks as f64)),
        ]));
    }
    save_results("ablation_block", &Json::Arr(json), Some(&table.to_csv()));
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    // Smoke tests: each experiment runs end-to-end at tiny scale and emits a
    // well-formed table. Full-scale runs happen in `cargo bench`.

    #[test]
    fn table5_smoke() {
        let mut s = BenchScale::smoke();
        s.nnz = 8_000;
        s.epochs = 1;
        let t = table5(&s);
        assert_eq!(t.rows.len(), 8); // 4 algos × {Factor, Core}
        let rendered = t.render();
        assert!(rendered.contains("cuFasterTucker"));
        // the Table V split: staging / refresh / sweep per dataset
        for col in ["staging", "refresh", "sweep"] {
            assert!(rendered.contains(col), "missing {col} column");
        }
    }

    #[test]
    fn fig4a_smoke() {
        std::env::set_var("FT_MAX_ORDER", "4");
        std::env::set_var("FT_ORDER_DIM", "40");
        std::env::set_var("FT_ORDER_NNZ", "4000");
        let mut s = BenchScale::smoke();
        s.nnz = 4_000;
        let t = fig4a(&s);
        assert_eq!(t.rows.len(), 2); // orders 3..=4
        std::env::remove_var("FT_MAX_ORDER");
        std::env::remove_var("FT_ORDER_DIM");
        std::env::remove_var("FT_ORDER_NNZ");
    }

    #[test]
    fn calibrate_flops_positive() {
        assert!(calibrate_flops() >= 1e9);
    }

    /// PR 2 bench-smoke guarantee: a session builds its `(storage, chain)`
    /// structures exactly once — the epoch path sweeps the cached
    /// `PreparedStorage` and never re-partitions, so measured iteration
    /// time excludes staging by construction.
    #[test]
    fn epoch_sweeps_exclude_staging() {
        let mut s = BenchScale::smoke();
        s.nnz = 8_000;
        let data = dataset("netflix-like", &s);
        for algo in [Algo::FastTucker, Algo::FasterTucker] {
            let mut session =
                Session::new(algo, s.cfg(&data), &data).expect("session");
            let staged = session.prep_stats().clone();
            assert_eq!(staged.builds, 1);
            for _ in 0..2 {
                session.factor_pass();
                session.core_pass();
            }
            session.run(1, None);
            // still the same single build, with identical staging seconds:
            // nothing on the pass/epoch path restaged the storage
            assert_eq!(session.prep_stats().builds, 1, "{}", algo.name());
            assert_eq!(
                session.prep_stats().total_seconds,
                staged.total_seconds,
                "{}",
                algo.name()
            );
        }
    }

    /// Load-balance numbers are asserted, not just printed: the measured
    /// per-worker block counts must tile the B-CSF block partition exactly,
    /// and both imbalance metrics must sit in their mathematical ranges.
    #[test]
    fn balance_stats_are_asserted_not_just_printed() {
        let mut s = BenchScale::smoke();
        s.nnz = 8_000;
        let data = dataset("netflix-like", &s);
        let workers = 4usize;
        let mut cfg = s.cfg(&data);
        cfg.workers = workers;
        cfg.block_nnz = 512;
        cfg.fiber_threshold = 64;
        let mut session =
            Session::new(Algo::FasterTucker, cfg, &data).expect("session");
        session.factor_pass();
        let ws = session
            .factor_worker_stats()
            .expect("engine pass records worker stats");
        // every scheduled block was claimed by exactly one worker
        let balance = session.balance_stats().expect("bcsf balance stats");
        let expected_blocks: usize = balance.iter().map(|b| b.num_blocks).sum();
        assert_eq!(ws.total_blocks(), expected_blocks);
        assert_eq!(ws.blocks.len(), workers);
        let imb = ws.imbalance();
        assert!(
            imb >= 1.0 - 1e-9 && imb <= workers as f64 + 1e-9,
            "worker imbalance {imb} outside [1, {workers}]"
        );
        // claimed-nnz accounting (LPT packing): every non-zero of every
        // mode pass is charged to exactly one worker — the *measured* load
        // figure, tighter than block counts because blocks are only equal
        // up to the greedy target+threshold bound
        let expected_nnz: usize = balance
            .iter()
            .map(|b| (b.mean_block_nnz * b.num_blocks as f64).round() as usize)
            .sum();
        assert_eq!(ws.total_nnz(), expected_nnz);
        let nimb = ws.nnz_imbalance();
        assert!(
            nimb >= 1.0 - 1e-9 && nimb <= workers as f64 + 1e-9,
            "claimed-nnz imbalance {nimb} outside [1, {workers}]"
        );
        // busy-time skew obeys the same max/mean bounds as claimed nnz,
        // and the pass actually accumulated busy time to measure
        assert!(ws.busy.iter().sum::<f64>() > 0.0, "workers recorded busy seconds");
        let limb = ws.latency_imbalance();
        assert!(
            limb >= 1.0 - 1e-9 && limb <= workers as f64 + 1e-9,
            "busy-seconds imbalance {limb} outside [1, {workers}]"
        );
        // Per-lease accounting: run the same session through a shared
        // executor on a leased worker subset. The pass's WorkerStats are
        // the *per-lease* stats — lease-sized, with every claimed non-zero
        // attributed inside the lease — and the executor's totals charge
        // only the leased slots (the aggregation fix: concurrent leases
        // used to pile onto slot 0).
        let lease = 2usize;
        let ex = std::sync::Arc::new(crate::sched::Executor::new(workers));
        session.set_executor(Some(ex.clone()));
        session.set_lease_workers(Some(lease));
        session.factor_pass();
        let ls = session
            .factor_worker_stats()
            .expect("leased engine pass records worker stats");
        assert_eq!(ls.blocks.len(), lease, "stats are lease-sized");
        assert_eq!(ls.total_blocks(), expected_blocks);
        assert_eq!(ls.total_nnz(), expected_nnz);
        let lease_nimb = ls.nnz_imbalance();
        assert!(
            lease_nimb >= 1.0 - 1e-9 && lease_nimb <= lease as f64 + 1e-9,
            "per-lease claimed-nnz imbalance {lease_nimb} outside [1, {lease}]"
        );
        let lease_limb = ls.latency_imbalance();
        assert!(
            lease_limb >= 1.0 - 1e-9 && lease_limb <= lease as f64 + 1e-9,
            "per-lease busy-seconds imbalance {lease_limb} outside [1, {lease}]"
        );
        let pool_total = ex.total_stats();
        assert_eq!(pool_total.total_nnz(), expected_nnz);
        assert_eq!(
            pool_total.nnz.iter().skip(lease).sum::<usize>(),
            0,
            "unleased slots must stay uncharged"
        );
        session.set_executor(None);
        session.set_lease_workers(None);

        // B-CSF structural balance: greedy close bound + sane statistics
        for b in &balance {
            assert!(
                b.max_block_nnz <= 512 + 64,
                "block {} exceeds target+threshold",
                b.max_block_nnz
            );
            assert!(b.min_block_nnz <= b.max_block_nnz);
            assert!(b.mean_block_nnz > 0.0);
            assert!(b.block_cv >= 0.0);
            assert!(b.num_tasks >= b.num_fibers);
        }
    }
}
