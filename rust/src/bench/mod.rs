//! Benchmark harness (no `criterion` offline): timing statistics, table
//! rendering, result persistence, and the [`experiments`] that regenerate
//! every table and figure of the paper. The `cargo bench` targets in
//! `rust/benches/` are thin wrappers over [`experiments`].

pub mod experiments;

use crate::util::json::Json;
use std::path::Path;

/// Timing statistics over repeated runs.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Mean seconds over the timed runs.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Fastest run.
    pub min: f64,
    /// Slowest run.
    pub max: f64,
    /// Number of timed runs.
    pub runs: usize,
}

impl Stats {
    /// Summarize a set of timing samples (seconds).
    pub fn from_samples(samples: &[f64]) -> Stats {
        let n = samples.len().max(1) as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        Stats {
            mean,
            std: var.sqrt(),
            min: samples.iter().copied().fold(f64::INFINITY, f64::min),
            max: samples.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            runs: samples.len(),
        }
    }

    /// JSON form for the persisted result files.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean", Json::num(self.mean)),
            ("std", Json::num(self.std)),
            ("min", Json::num(self.min)),
            ("max", Json::num(self.max)),
            ("runs", Json::num(self.runs as f64)),
        ])
    }
}

/// Time `f` with warmup; returns stats over `iters` timed runs.
pub fn time_fn(warmup: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats::from_samples(&samples)
}

/// A printable results table (fixed-width, like the paper's tables).
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (each as wide as `headers`).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a caption and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    /// Fixed-width text rendering (paper-table style).
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (k, cell) in row.iter().enumerate() {
                widths[k] = widths[k].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for k in 0..ncols {
                line.push_str(&format!("{:<width$} | ", cells[k], width = widths[k]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let sep: usize = widths.iter().sum::<usize>() + 3 * ncols + 1;
        out.push_str(&"-".repeat(sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// CSV rendering (header row + data rows).
    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

/// Write a result artifact (JSON) plus optional CSV into `results/`.
pub fn save_results(name: &str, json: &Json, csv: Option<&str>) {
    let dir = Path::new("results");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let _ = std::fs::write(dir.join(format!("{name}.json")), json.to_string_pretty());
    if let Some(csv) = csv {
        let _ = std::fs::write(dir.join(format!("{name}.csv")), csv);
    }
}

/// Read a bench-scale knob from the environment with a default.
pub fn env_scale(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.runs, 3);
        assert!((s.std - (2.0f64 / 3.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn time_fn_counts_runs() {
        let mut calls = 0;
        let s = time_fn(2, 5, || {
            calls += 1;
        });
        assert_eq!(calls, 7);
        assert_eq!(s.runs, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new("Demo", &["algo", "seconds"]);
        t.row(vec!["x".into(), "1.5".into()]);
        let r = t.render();
        assert!(r.contains("Demo"));
        assert!(r.contains("algo"));
        assert!(r.contains("1.5"));
        assert_eq!(t.to_csv(), "algo,seconds\nx,1.5\n");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn env_scale_parses() {
        std::env::set_var("FT_TEST_SCALE_X", "123");
        assert_eq!(env_scale("FT_TEST_SCALE_X", 5), 123);
        std::env::remove_var("FT_TEST_SCALE_X");
        assert_eq!(env_scale("FT_TEST_SCALE_X", 5), 5);
    }
}
