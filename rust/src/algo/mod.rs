//! The sparse FastTucker / FasterTucker SGD algorithms (paper §II-D, §III).
//!
//! All variants optimize the same objective (paper eq. 6) with the same
//! per-element updates (eq. 9–11); they differ *only* in how the dominant
//! intermediates are obtained — which is exactly the paper's ablation
//! (Table V). Since every variant shares one update schema, the hot loop
//! lives ONCE, in the generic [`engine`], and each variant is an
//! instantiation along two pluggable axes (plus the update target):
//!
//! | variant                    | [`engine::SparseStorage`]          | [`engine::ChainStrategy`] |
//! |----------------------------|------------------------------------|---------------------------|
//! | [`fastucker`] (baseline)   | `CooBlocks` (per-element groups)   | `OnTheFly`                |
//! | `fastertucker` (COO)       | `CooBlocks` (per-element groups)   | `Tables`                  |
//! | `fastertucker` (B-CSF abl.)| `BcsfPerElement` (fiber order)     | `Tables`                  |
//! | `fastertucker` (full)      | `BcsfShared` (fiber-shared groups) | `TablesPrefixCached`      |
//!
//! The layering is documented end-to-end in `ARCHITECTURE.md`
//! (tensor → engine → coordinator); `tests/engine_parity.rs` pins every
//! instantiation to the pre-engine reference loops bit-for-bit on one
//! worker. Full-core baselines (`cuTucker`, `P-Tucker`) keep their own
//! loops under [`crate::baselines`] — they update a dense `J^N` core, a
//! different schema.

pub mod engine;
pub mod grad;
pub mod kernels;
pub mod fastucker;
pub mod fastertucker;

use anyhow::bail;

/// Algorithm selector used by the CLI, coordinator and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// cuFastTucker: COO, all intermediates recomputed on the fly.
    FastTucker,
    /// cuFasterTucker_COO: reusable C tables, COO traversal.
    FasterTuckerCoo,
    /// cuFasterTucker_B-CSF: reusable C tables, B-CSF traversal order, but
    /// the fiber-shared intermediate still recomputed per non-zero.
    FasterTuckerBcsf,
    /// cuFasterTucker (full): C tables + fiber-shared intermediates, B-CSF.
    FasterTucker,
    /// cuTucker baseline: SGD over the *full* core tensor G ∈ R^{J^N}.
    CuTucker,
    /// P-Tucker baseline: row-wise ALS over the full core tensor.
    PTucker,
}

impl Algo {
    /// Parse a CLI name/alias (`fastertucker`, `coo`, `bcsf`, ...).
    pub fn parse(s: &str) -> anyhow::Result<Algo> {
        Ok(match s {
            "fastucker" | "cufastucker" | "fast" => Algo::FastTucker,
            "fastertucker-coo" | "coo" => Algo::FasterTuckerCoo,
            "fastertucker-bcsf" => Algo::FasterTuckerBcsf,
            "fastertucker" | "faster" | "bcsf" => Algo::FasterTucker,
            "cutucker" => Algo::CuTucker,
            "ptucker" => Algo::PTucker,
            other => bail!(
                "unknown algorithm '{other}' \
                 (fastucker|fastertucker-coo|fastertucker-bcsf|fastertucker|cutucker|ptucker)"
            ),
        })
    }

    /// Paper-style display name (`cuFasterTucker`, `P-Tucker`, ...).
    pub fn name(&self) -> &'static str {
        match self {
            Algo::FastTucker => "cuFastTucker",
            Algo::FasterTuckerCoo => "cuFasterTucker_COO",
            Algo::FasterTuckerBcsf => "cuFasterTucker_B-CSF",
            Algo::FasterTucker => "cuFasterTucker",
            Algo::CuTucker => "cuTucker",
            Algo::PTucker => "P-Tucker",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(Algo::parse("fastucker").unwrap(), Algo::FastTucker);
        assert_eq!(Algo::parse("coo").unwrap(), Algo::FasterTuckerCoo);
        assert_eq!(Algo::parse("bcsf").unwrap(), Algo::FasterTucker);
        assert_eq!(Algo::parse("cutucker").unwrap(), Algo::CuTucker);
        assert_eq!(Algo::parse("ptucker").unwrap(), Algo::PTucker);
        assert!(Algo::parse("magic").is_err());
    }

    #[test]
    fn names_match_paper() {
        assert_eq!(Algo::FasterTucker.name(), "cuFasterTucker");
        assert_eq!(Algo::FastTucker.name(), "cuFastTucker");
    }
}
