//! cuFastTucker baseline (paper [28], Table V rows "cuFastTucker"), as an
//! instantiation of the generic [`super::engine`].
//!
//! COO traversal ([`CooBlocks`]); for every non-zero, the chain scalars
//! `a_{i_{n'}}·b_{:,r}^{(n')}` are recomputed on the fly
//! ([`ChainStrategy::OnTheFly`]) — `(N−1)·J·R` multiplications per non-zero
//! per mode, the cost FasterTucker eliminates. Updates themselves
//! (eq. 9–11) are identical to FasterTucker, which is why the convergence
//! curves coincide (paper Fig. 3) while the iteration time differs by ~15×.
//!
//! FastTucker maintains no `C` tables during training, so both epochs run
//! with a no-op refresh; the coordinator syncs the tables once per epoch for
//! evaluation.

use crate::config::TrainConfig;
use crate::model::ModelState;
use crate::tensor::coo::{CooBlocks, CooTensor};

use super::engine::{self, refresh_none, ChainStrategy};

/// Modes other than `n`, in ascending order.
pub(crate) fn other_modes(order: usize, n: usize) -> Vec<usize> {
    (0..order).filter(|&m| m != n).collect()
}

/// One full factor-update epoch: for each mode `n` in turn, SGD-update every
/// row of `A^(n)` from every non-zero (Hogwild across workers).
pub fn factor_epoch(model: &mut ModelState, data: &CooTensor, cfg: &TrainConfig) {
    let storage = CooBlocks::new(data, cfg.block_nnz);
    engine::factor_epoch(model, &storage, ChainStrategy::OnTheFly, cfg, &refresh_none);
}

/// One full core-update epoch: for each mode `n`, accumulate the full-batch
/// gradient of `B^(n)` over all non-zeros, then apply it once
/// (paper Algorithm 5 accumulates in global memory and updates at the end).
pub fn core_epoch(model: &mut ModelState, data: &CooTensor, cfg: &TrainConfig) {
    let storage = CooBlocks::new(data, cfg.block_nnz);
    engine::core_epoch(model, &storage, ChainStrategy::OnTheFly, cfg, &refresh_none);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::metrics::rmse_mae;

    fn setup(workers: usize) -> (ModelState, CooTensor, TrainConfig) {
        let t = recommender(&RecommenderSpec::tiny(), 11);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers,
            block_nnz: 512,
            ..TrainConfig::default()
        };
        let model = ModelState::init(&cfg, 3);
        (model, t, cfg)
    }

    #[test]
    fn factor_epoch_reduces_error_serial() {
        let (mut model, t, cfg) = setup(1);
        model.refresh_all_c();
        let (before, _) = rmse_mae(&model, &t, 1);
        for _ in 0..3 {
            factor_epoch(&mut model, &t, &cfg);
        }
        model.refresh_all_c();
        let (after, _) = rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    #[test]
    fn factor_epoch_reduces_error_parallel() {
        let (mut model, t, cfg) = setup(4);
        model.refresh_all_c();
        let (before, _) = rmse_mae(&model, &t, 1);
        for _ in 0..3 {
            factor_epoch(&mut model, &t, &cfg);
        }
        model.refresh_all_c();
        let (after, _) = rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    #[test]
    fn core_epoch_reduces_error() {
        let (mut model, t, cfg) = setup(2);
        model.refresh_all_c();
        let (before, _) = rmse_mae(&model, &t, 1);
        for _ in 0..5 {
            core_epoch(&mut model, &t, &cfg);
        }
        model.refresh_all_c();
        let (after, _) = rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    #[test]
    fn serial_epoch_is_deterministic() {
        let (mut m1, t, cfg) = setup(1);
        let mut m2 = m1.clone();
        factor_epoch(&mut m1, &t, &cfg);
        factor_epoch(&mut m2, &t, &cfg);
        for n in 0..3 {
            assert_eq!(m1.factors[n].max_abs_diff(&m2.factors[n]), 0.0);
        }
    }

    #[test]
    fn factors_stay_finite() {
        let (mut model, t, cfg) = setup(2);
        for _ in 0..5 {
            factor_epoch(&mut model, &t, &cfg);
            core_epoch(&mut model, &t, &cfg);
        }
        for n in 0..3 {
            assert!(model.factors[n].data().iter().all(|x| x.is_finite()));
            assert!(model.cores[n].data().iter().all(|x| x.is_finite()));
        }
    }
}
