//! cuFastTucker baseline (paper [28], Table V rows "cuFastTucker").
//!
//! COO traversal; for every non-zero, the chain scalars
//! `a_{i_{n'}}·b_{:,r}^{(n')}` are recomputed on the fly — `(N−1)·J·R`
//! multiplications per non-zero per mode, the cost FasterTucker eliminates.
//! Updates themselves (eq. 9–11) are identical to FasterTucker, which is
//! why the convergence curves coincide (paper Fig. 3) while the iteration
//! time differs by ~15×.

use crate::config::TrainConfig;
use crate::linalg::Matrix;
use crate::model::ModelState;
use crate::sched::pool::parallel_reduce;
use crate::sched::racy::RacyMatrix;
use crate::tensor::coo::CooTensor;
use crate::util::ceil_div;

use super::grad::{accumulate_core_grad, apply_core_grad, chain_v_on_the_fly, fiber_w, Scratch};

/// Modes other than `n`, in ascending order.
pub(crate) fn other_modes(order: usize, n: usize) -> Vec<usize> {
    (0..order).filter(|&m| m != n).collect()
}

/// One full factor-update epoch: for each mode `n` in turn, SGD-update every
/// row of `A^(n)` from every non-zero (Hogwild across workers).
pub fn factor_epoch(model: &mut ModelState, data: &CooTensor, cfg: &TrainConfig) {
    let order = model.order();
    let nnz = data.nnz();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let block = cfg.block_nnz.max(1);
    let num_blocks = ceil_div(nnz, block);
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;

    for n in 0..order {
        let modes = other_modes(order, n);
        // take A^(n) out so workers can racy-write it while reading the rest
        let mut target = std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target);
            let factors = &model.factors;
            let cores = &model.cores;
            let core_n = &model.cores[n];
            parallel_reduce(
                workers,
                num_blocks,
                || Scratch::new(order, j, r),
                |s, _w, b| {
                    let lo = b * block;
                    let hi = (lo + block).min(nnz);
                    for e in lo..hi {
                        let coords = data.index(e);
                        let x = data.value(e);
                        s.sub.clear();
                        s.sub.extend(modes.iter().map(|&m| coords[m]));
                        let Scratch { sub, v, .. } = s;
                        chain_v_on_the_fly(factors, cores, &modes, sub, v);
                        fiber_w(core_n, &s.v, &mut s.w);
                        let i = coords[n] as usize;
                        let e_val = x - racy.row_dot(i, &s.w);
                        racy.row_sgd_update(i, scale, cfg.lr_a * e_val, &s.w);
                    }
                },
                |_acc, _other| {},
            );
        }
        model.factors[n] = target;
    }
}

/// One full core-update epoch: for each mode `n`, accumulate the full-batch
/// gradient of `B^(n)` over all non-zeros, then apply it once
/// (paper Algorithm 5 accumulates in global memory and updates at the end).
pub fn core_epoch(model: &mut ModelState, data: &CooTensor, cfg: &TrainConfig) {
    let order = model.order();
    let nnz = data.nnz();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let block = cfg.block_nnz.max(1);
    let num_blocks = ceil_div(nnz, block);

    for n in 0..order {
        let modes = other_modes(order, n);
        let factors = &model.factors;
        let cores = &model.cores;
        let core_n = &model.cores[n];
        let grad = parallel_reduce(
            workers,
            num_blocks,
            || Scratch::new(order, j, r),
            |s, _w, b| {
                let lo = b * block;
                let hi = (lo + block).min(nnz);
                for e in lo..hi {
                    let coords = data.index(e);
                    let x = data.value(e);
                    s.sub.clear();
                    s.sub.extend(modes.iter().map(|&m| coords[m]));
                    let Scratch { sub, v, .. } = s;
                    chain_v_on_the_fly(factors, cores, &modes, sub, v);
                    fiber_w(core_n, &s.v, &mut s.w);
                    let a = factors[n].row(coords[n] as usize);
                    let xhat = crate::linalg::dot(a, &s.w);
                    accumulate_core_grad(&mut s.grad, x - xhat, &s.v, a);
                }
            },
            |acc, other| {
                for (g, o) in acc.grad.data_mut().iter_mut().zip(other.grad.data()) {
                    *g += o;
                }
            },
        )
        .grad;
        apply_core_grad(&mut model.cores[n], &grad, nnz, cfg.lr_b, cfg.lambda_b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::metrics::rmse_mae;

    fn setup(workers: usize) -> (ModelState, CooTensor, TrainConfig) {
        let t = recommender(&RecommenderSpec::tiny(), 11);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers,
            block_nnz: 512,
            ..TrainConfig::default()
        };
        let model = ModelState::init(&cfg, 3);
        (model, t, cfg)
    }

    #[test]
    fn factor_epoch_reduces_error_serial() {
        let (mut model, t, cfg) = setup(1);
        model.refresh_all_c();
        let (before, _) = rmse_mae(&model, &t, 1);
        for _ in 0..3 {
            factor_epoch(&mut model, &t, &cfg);
        }
        model.refresh_all_c();
        let (after, _) = rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    #[test]
    fn factor_epoch_reduces_error_parallel() {
        let (mut model, t, cfg) = setup(4);
        model.refresh_all_c();
        let (before, _) = rmse_mae(&model, &t, 1);
        for _ in 0..3 {
            factor_epoch(&mut model, &t, &cfg);
        }
        model.refresh_all_c();
        let (after, _) = rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    #[test]
    fn core_epoch_reduces_error() {
        let (mut model, t, cfg) = setup(2);
        model.refresh_all_c();
        let (before, _) = rmse_mae(&model, &t, 1);
        for _ in 0..5 {
            core_epoch(&mut model, &t, &cfg);
        }
        model.refresh_all_c();
        let (after, _) = rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    #[test]
    fn serial_epoch_is_deterministic() {
        let (mut m1, t, cfg) = setup(1);
        let mut m2 = m1.clone();
        factor_epoch(&mut m1, &t, &cfg);
        factor_epoch(&mut m2, &t, &cfg);
        for n in 0..3 {
            assert_eq!(m1.factors[n].max_abs_diff(&m2.factors[n]), 0.0);
        }
    }

    #[test]
    fn factors_stay_finite() {
        let (mut model, t, cfg) = setup(2);
        for _ in 0..5 {
            factor_epoch(&mut model, &t, &cfg);
            core_epoch(&mut model, &t, &cfg);
        }
        for n in 0..3 {
            assert!(model.factors[n].data().iter().all(|x| x.is_finite()));
            assert!(model.cores[n].data().iter().all(|x| x.is_finite()));
        }
    }
}
