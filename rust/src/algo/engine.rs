//! The generic epoch engine — ONE stochastic update schema, instantiated.
//!
//! The paper's four hot loops (Algorithms 2–5) are a single computation:
//!
//! ```text
//! for each mode n:
//!   for each block (in parallel, dynamically scheduled):        ShardPlan
//!     for each shared-coordinate group (fiber or element):      SparseStorage
//!       v ← chain of a·b scalars over the other modes           ChainStrategy
//!       w ← B⁽ⁿ⁾ v
//!       for each non-zero of the group:
//!         update factor row (Hogwild) or core gradient          UpdateTarget
//!   finalize: reinstate factor / apply core gradient, refresh C⁽ⁿ⁾
//! ```
//!
//! The three orthogonal axes are pluggable:
//!
//! * [`SparseStorage`] — who walks the non-zeros and how they group:
//!   COO element blocks ([`crate::tensor::coo::CooBlocks`]), B-CSF blocks
//!   with fiber-shared groups ([`crate::tensor::bcsf::BcsfShared`]), or
//!   B-CSF order without sharing ([`crate::tensor::bcsf::BcsfPerElement`],
//!   the paper's Table V ablation row).
//! * [`ChainStrategy`] — where the chain scalars come from: on-the-fly dot
//!   products (FastTucker), the precomputed `C` tables (FasterTucker), or
//!   the tables with Algorithm-4 prefix reuse across consecutive fibers.
//! * [`UpdateTarget`] — what the visit updates: Hogwild factor-row SGD
//!   ([`FactorTarget`]) or per-worker core-gradient accumulation merged
//!   after the pass ([`CoreTarget`]).
//!
//! Every public epoch entry point in [`super::fastucker`] and
//! [`super::fastertucker`] is a one-line instantiation of [`run_epoch`];
//! `tests/engine_parity.rs` proves each instantiation bit-identical to the
//! pre-engine reference loops on one worker.

use crate::config::TrainConfig;
use crate::linalg::Matrix;
use crate::model::ModelState;
use crate::sched::pool::WorkerStats;
use crate::sched::racy::RacyMatrix;
use crate::sched::shard::ShardPlan;

use super::grad::{
    accumulate_core_grad, apply_core_grad, chain_v_from_tables, chain_v_on_the_fly,
    chain_v_prefix_cached, fiber_w, Scratch,
};

/// How the coordinator refreshes `C^(n)` after a mode update (in-crate GEMM
/// or the AOT/PJRT kernel — injected so the engine stays backend-agnostic).
pub type RefreshC<'a> = dyn Fn(&mut ModelState, usize) + 'a;

/// Default refresh: in-crate GEMM.
pub fn refresh_rust(model: &mut ModelState, n: usize) {
    model.refresh_c(n);
}

/// No-op refresh — for algorithms that keep no `C` tables during training
/// (the FastTucker baseline syncs them once per epoch in the coordinator).
pub fn refresh_none(_model: &mut ModelState, _n: usize) {}

/// Where the chain scalars `v_r = Π_{m≠n} a_{i_m}·b^{(m)}_{:,r}` come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChainStrategy {
    /// Recompute every `a·b` dot product per visited group — the FastTucker
    /// baseline's `(N−1)·J·R` multiplications per non-zero.
    OnTheFly,
    /// Read the precomputed `C^(n) = A^(n) B^(n)` tables per visited group.
    Tables,
    /// `Tables`, plus Algorithm-4 prefix-product reuse across consecutive
    /// fibers of a block (only meaningful for fiber-ordered storage).
    TablesPrefixCached,
}

impl ChainStrategy {
    /// The chain source each FastTucker-family algorithm uses — one half of
    /// the `(storage, chain)` instantiation that
    /// [`crate::tensor::prepared::PreparedStorage`] builds exactly once per
    /// session. `None` for the full-core baselines, which do not run on the
    /// engine.
    pub fn for_algo(algo: super::Algo) -> Option<ChainStrategy> {
        use super::Algo;
        match algo {
            Algo::FastTucker => Some(ChainStrategy::OnTheFly),
            Algo::FasterTuckerCoo | Algo::FasterTuckerBcsf => {
                Some(ChainStrategy::Tables)
            }
            Algo::FasterTucker => Some(ChainStrategy::TablesPrefixCached),
            Algo::CuTucker | Algo::PTucker => None,
        }
    }
}

/// Which model component an epoch pass updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateKind {
    /// SGD on the mode's factor matrix `A^(n)` (Hogwild row updates).
    Factor,
    /// Full-batch gradient on the mode's core matrix `B^(n)`.
    Core,
}

/// Receives the element stream of one storage block during an epoch pass.
///
/// The contract mirrors the paper's kernel structure: `group` delivers the
/// shared (non-update-mode) coordinates once per fiber — or once per element
/// for storages without sharing — and `leaf` delivers each non-zero of the
/// current group as `(update-mode row, value)`.
pub trait BlockSink {
    /// A new shared-coordinate group. `coords[k]` pairs with the storage's
    /// [`SparseStorage::chain_modes`] entry `k`.
    fn group(&mut self, coords: &[u32]);
    /// One non-zero of the current group.
    fn leaf(&mut self, row: usize, x: f32);
}

/// A sparse-tensor layout the engine can run an epoch over.
///
/// Implementations stream *blocks* — the schedulable work units a worker
/// claims — and, within a block, groups of non-zeros that share their
/// non-update-mode coordinates. Implemented by
/// [`crate::tensor::coo::CooBlocks`] (element stream, groups of one) and the
/// B-CSF adapters in [`crate::tensor::bcsf`] (fiber/task streams).
pub trait SparseStorage: Sync {
    /// Schedulable block count for the mode-`n` pass.
    fn num_blocks(&self, n: usize) -> usize;
    /// Non-zero count seen by the mode-`n` pass (core-gradient normalizer).
    fn nnz(&self, n: usize) -> usize;
    /// The non-update modes, in the order their coordinates are handed to
    /// [`BlockSink::group`] (ascending for COO, CSF tree order for B-CSF).
    fn chain_modes(&self, n: usize) -> Vec<usize>;
    /// Stream block `b` of the mode-`n` pass into `sink`.
    fn drive_block(&self, n: usize, b: usize, sink: &mut dyn BlockSink);
}

/// What one epoch pass updates per visited non-zero. `visit` runs in the
/// hot loop with `v`/`w` already computed in the scratch; `merge` folds a
/// finished worker's scratch accumulator into another's.
pub trait UpdateTarget: Sync {
    fn visit(&self, s: &mut Scratch, row: usize, x: f32);
    fn merge(&self, acc: &mut Scratch, other: Scratch);
}

/// Hogwild factor-row SGD: `a ← (1−γλ)a + γe·w` (paper eq. 10).
pub struct FactorTarget<'a> {
    pub racy: &'a RacyMatrix<'a>,
    pub scale: f32,
    pub lr: f32,
}

impl UpdateTarget for FactorTarget<'_> {
    #[inline]
    fn visit(&self, s: &mut Scratch, row: usize, x: f32) {
        let e = x - self.racy.row_dot(row, &s.w);
        self.racy.row_sgd_update(row, self.scale, self.lr * e, &s.w);
    }
    fn merge(&self, _acc: &mut Scratch, _other: Scratch) {}
}

/// Per-worker core-gradient accumulation: `G[:,r] += e·v_r·a` (paper
/// eq. 11), merged across workers after the pass.
pub struct CoreTarget<'a> {
    pub factor_n: &'a Matrix,
}

impl UpdateTarget for CoreTarget<'_> {
    #[inline]
    fn visit(&self, s: &mut Scratch, row: usize, x: f32) {
        let a = self.factor_n.row(row);
        let Scratch { v, w, grad, .. } = s;
        let xhat = crate::linalg::dot(a, w);
        accumulate_core_grad(grad, x - xhat, v, a);
    }
    fn merge(&self, acc: &mut Scratch, other: Scratch) {
        for (g, o) in acc.grad.data_mut().iter_mut().zip(other.grad.data()) {
            *g += o;
        }
    }
}

/// Chain source with the model borrows resolved for one mode pass.
#[derive(Clone, Copy)]
enum ChainSource<'a> {
    OnTheFly { factors: &'a [Matrix], cores: &'a [Matrix] },
    Tables(&'a [Matrix]),
    Cached(&'a [Matrix]),
}

fn resolve_chain<'m>(chain: ChainStrategy, model: &'m ModelState) -> ChainSource<'m> {
    match chain {
        ChainStrategy::OnTheFly => ChainSource::OnTheFly {
            factors: &model.factors,
            cores: &model.cores,
        },
        ChainStrategy::Tables => ChainSource::Tables(&model.c_tables),
        ChainStrategy::TablesPrefixCached => ChainSource::Cached(&model.c_tables),
    }
}

/// The per-worker state threaded through a block stream: chain inputs, the
/// mode's core matrix, the update target, and the scratch buffers.
struct EngineSink<'a, T: UpdateTarget> {
    chain: ChainSource<'a>,
    modes: &'a [usize],
    core_n: &'a Matrix,
    target: &'a T,
    s: Scratch,
}

impl<T: UpdateTarget> EngineSink<'_, T> {
    /// Block boundary: invalidate the fiber prefix cache (a new block's
    /// first fiber has no guaranteed relation to the previous one).
    fn begin_block(&mut self) {
        self.s.reset_prefix();
    }
}

impl<T: UpdateTarget> BlockSink for EngineSink<'_, T> {
    #[inline]
    fn group(&mut self, coords: &[u32]) {
        match self.chain {
            ChainSource::Tables(c) => {
                chain_v_from_tables(c, self.modes, coords, &mut self.s.v)
            }
            ChainSource::Cached(c) => {
                chain_v_prefix_cached(c, self.modes, coords, &mut self.s)
            }
            ChainSource::OnTheFly { factors, cores } => {
                chain_v_on_the_fly(factors, cores, self.modes, coords, &mut self.s.v)
            }
        }
        fiber_w(self.core_n, &self.s.v, &mut self.s.w);
    }

    #[inline]
    fn leaf(&mut self, row: usize, x: f32) {
        self.target.visit(&mut self.s, row, x);
    }
}

/// One full epoch of `kind` updates over `storage`: all modes in turn,
/// refreshing `C^(n)` through `refresh` after each mode. Returns the
/// accumulated per-worker scheduling stats of the epoch.
pub fn run_epoch(
    model: &mut ModelState,
    storage: &dyn SparseStorage,
    chain: ChainStrategy,
    kind: UpdateKind,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) -> WorkerStats {
    match kind {
        UpdateKind::Factor => factor_epoch(model, storage, chain, cfg, refresh),
        UpdateKind::Core => core_epoch(model, storage, chain, cfg, refresh),
    }
}

/// One factor-update epoch (paper Algorithms 2/4): for each mode, take
/// `A^(n)` out for Hogwild writes, stream every block, reinstate, refresh.
pub fn factor_epoch(
    model: &mut ModelState,
    storage: &dyn SparseStorage,
    chain: ChainStrategy,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) -> WorkerStats {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;
    let mut total = WorkerStats::with_workers(workers);

    for n in 0..order {
        let modes = storage.chain_modes(n);
        let plan = ShardPlan::new(workers, storage.num_blocks(n));
        let mut target_m =
            std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target_m);
            let tgt = FactorTarget { racy: &racy, scale, lr: cfg.lr_a };
            let chain_src = resolve_chain(chain, model);
            let core_n = &model.cores[n];
            let (_, stats) = plan.execute_with_stats(
                || EngineSink {
                    chain: chain_src,
                    modes: modes.as_slice(),
                    core_n,
                    target: &tgt,
                    s: Scratch::new(order, j, r),
                },
                |sink, _w, b| {
                    sink.begin_block();
                    storage.drive_block(n, b, sink);
                },
                |acc, other| tgt.merge(&mut acc.s, other.s),
            );
            total.absorb(&stats);
        }
        model.factors[n] = target_m;
        refresh(model, n);
    }
    total
}

/// One core-update epoch (paper Algorithms 3/5): for each mode, accumulate
/// the full-batch gradient of `B^(n)` per worker, merge, apply once,
/// refresh.
pub fn core_epoch(
    model: &mut ModelState,
    storage: &dyn SparseStorage,
    chain: ChainStrategy,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) -> WorkerStats {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let mut total = WorkerStats::with_workers(workers);

    for n in 0..order {
        let modes = storage.chain_modes(n);
        let nnz = storage.nnz(n);
        let plan = ShardPlan::new(workers, storage.num_blocks(n));
        let (grad, stats) = {
            let chain_src = resolve_chain(chain, model);
            let core_n = &model.cores[n];
            let tgt = CoreTarget { factor_n: &model.factors[n] };
            let (sink, stats) = plan.execute_with_stats(
                || EngineSink {
                    chain: chain_src,
                    modes: modes.as_slice(),
                    core_n,
                    target: &tgt,
                    s: Scratch::new(order, j, r),
                },
                |sink, _w, b| {
                    sink.begin_block();
                    storage.drive_block(n, b, sink);
                },
                |acc, other| tgt.merge(&mut acc.s, other.s),
            );
            (sink.s.grad, stats)
        };
        apply_core_grad(&mut model.cores[n], &grad, nnz, cfg.lr_b, cfg.lambda_b);
        refresh(model, n);
        total.absorb(&stats);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::tensor::bcsf::{BcsfPerElement, BcsfShared, BcsfTensor};
    use crate::tensor::coo::{CooBlocks, CooTensor};

    fn setup() -> (ModelState, CooTensor, TrainConfig) {
        let t = recommender(&RecommenderSpec::tiny(), 77);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 1,
            block_nnz: 256,
            fiber_threshold: 16,
            ..TrainConfig::default()
        };
        let model = ModelState::init(&cfg, 5);
        (model, t, cfg)
    }

    #[test]
    fn storage_contracts_agree_on_totals() {
        let (_, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let bcsf: Vec<BcsfTensor> = (0..3)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let shared = BcsfShared::new(&bcsf);
        let per_elem = BcsfPerElement::new(&bcsf);
        for n in 0..3 {
            assert_eq!(coo.nnz(n), t.nnz());
            assert_eq!(shared.nnz(n), per_elem.nnz(n));
            assert!(coo.num_blocks(n) > 0);
            assert!(shared.num_blocks(n) > 0);
            assert_eq!(coo.chain_modes(n).len(), 2);
            assert_eq!(shared.chain_modes(n).len(), 2);
            assert!(!coo.chain_modes(n).contains(&n));
            assert!(!shared.chain_modes(n).contains(&n));
        }
    }

    /// Every storage must stream each non-zero exactly once per mode pass,
    /// with a group announced before its leaves.
    #[test]
    fn storages_stream_every_nnz_once() {
        struct Counter {
            groups: usize,
            leaves: usize,
            value_sum: f64,
            group_open: bool,
        }
        impl BlockSink for Counter {
            fn group(&mut self, coords: &[u32]) {
                assert!(!coords.is_empty());
                self.groups += 1;
                self.group_open = true;
            }
            fn leaf(&mut self, _row: usize, x: f32) {
                assert!(self.group_open, "leaf before any group");
                self.leaves += 1;
                self.value_sum += x as f64;
            }
        }

        let (_, t, cfg) = setup();
        let exact_sum: f64 = t.values().iter().map(|&v| v as f64).sum();
        let bcsf: Vec<BcsfTensor> = (0..3)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let shared = BcsfShared::new(&bcsf);
        let per_elem = BcsfPerElement::new(&bcsf);
        let storages: [&dyn SparseStorage; 3] = [&coo, &shared, &per_elem];
        for storage in storages {
            for n in 0..3 {
                let mut c = Counter {
                    groups: 0,
                    leaves: 0,
                    value_sum: 0.0,
                    group_open: false,
                };
                for b in 0..storage.num_blocks(n) {
                    storage.drive_block(n, b, &mut c);
                }
                assert_eq!(c.leaves, storage.nnz(n));
                assert!(c.groups >= 1 && c.groups <= c.leaves);
                assert!((c.value_sum - exact_sum).abs() < 1e-3);
            }
        }
    }

    /// The shared B-CSF stream must announce strictly fewer groups than
    /// leaves on a fiber-rich tensor (that is the whole point of sharing),
    /// while the per-element ablation announces exactly one per leaf.
    #[test]
    fn sharing_reduces_group_count() {
        struct Tally {
            groups: usize,
            leaves: usize,
        }
        impl BlockSink for Tally {
            fn group(&mut self, _coords: &[u32]) {
                self.groups += 1;
            }
            fn leaf(&mut self, _row: usize, _x: f32) {
                self.leaves += 1;
            }
        }
        let (_, t, cfg) = setup();
        let bcsf: Vec<BcsfTensor> = (0..3)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let shared = BcsfShared::new(&bcsf);
        let per_elem = BcsfPerElement::new(&bcsf);
        let count = |s: &dyn SparseStorage, n: usize| {
            let mut t = Tally { groups: 0, leaves: 0 };
            for b in 0..s.num_blocks(n) {
                s.drive_block(n, b, &mut t);
            }
            t
        };
        let mut any_shared_win = false;
        for n in 0..3 {
            let ts = count(&shared, n);
            let tp = count(&per_elem, n);
            assert_eq!(ts.leaves, tp.leaves);
            assert_eq!(tp.groups, tp.leaves);
            assert!(ts.groups <= tp.groups);
            if ts.groups < tp.groups {
                any_shared_win = true;
            }
        }
        assert!(any_shared_win, "no mode had any fiber with >1 leaf");
    }

    #[test]
    fn engine_factor_epoch_reduces_error_and_reports_stats() {
        let (mut model, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let (before, _) = crate::metrics::rmse_mae(&model, &t, 1);
        let mut stats = WorkerStats::with_workers(1);
        for _ in 0..3 {
            stats.absorb(&run_epoch(
                &mut model,
                &coo,
                ChainStrategy::Tables,
                UpdateKind::Factor,
                &cfg,
                &refresh_rust,
            ));
        }
        let (after, _) = crate::metrics::rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
        // 3 epochs × 3 modes × blocks-per-pass
        assert_eq!(stats.total_blocks(), 3 * 3 * coo.num_blocks(0));
    }

    #[test]
    fn engine_core_epoch_reduces_error() {
        let (mut model, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let (before, _) = crate::metrics::rmse_mae(&model, &t, 1);
        for _ in 0..5 {
            run_epoch(
                &mut model,
                &coo,
                ChainStrategy::Tables,
                UpdateKind::Core,
                &cfg,
                &refresh_rust,
            );
        }
        let (after, _) = crate::metrics::rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
    }
}
