//! The generic epoch engine — ONE stochastic update schema, instantiated.
//!
//! The paper's four hot loops (Algorithms 2–5) are a single computation:
//!
//! ```text
//! for each mode n:
//!   for each block (in parallel, LPT-ordered dynamic sched):   ShardPlan
//!     for each shared-coordinate group (fiber or element):      SparseStorage
//!       v ← chain of a·b scalars over the other modes           ChainStrategy
//!       w ← B⁽ⁿ⁾ v
//!       for each contiguous leaf run of the group:
//!         update factor rows (Hogwild) or core gradient         UpdateTarget
//!   finalize: reinstate factor / apply core gradient, refresh C⁽ⁿ⁾
//! ```
//!
//! The three orthogonal axes are pluggable:
//!
//! * [`SparseStorage`] — who walks the non-zeros and how they group:
//!   COO element blocks ([`crate::tensor::coo::CooBlocks`]), B-CSF blocks
//!   with fiber-shared groups ([`crate::tensor::bcsf::BcsfShared`]), or
//!   B-CSF order without sharing ([`crate::tensor::bcsf::BcsfPerElement`],
//!   the paper's Table V ablation row).
//! * [`ChainStrategy`] — where the chain scalars come from: on-the-fly dot
//!   products (FastTucker), the precomputed `C` tables (FasterTucker), or
//!   the tables with Algorithm-4 prefix reuse across consecutive fibers.
//! * [`UpdateTarget`] — what the visit updates: Hogwild factor-row SGD
//!   ([`FactorTarget`]) or per-worker core-gradient accumulation merged
//!   after the pass ([`CoreTarget`]).
//!
//! **Monomorphized hot path.** Since the batched-leaf rework there is no
//! `dyn` anywhere inside a pass: the epoch functions are generic over the
//! concrete `SparseStorage`, `drive_block` is generic over the concrete
//! [`BlockSink`], and storages hand each group's non-zeros to the sink as
//! contiguous **slices** ([`BlockSink::leaves`]) instead of one virtual
//! call per element. The whole group → chain → `fiber_w` → update pipeline
//! inlines; the only remaining dispatch is the per-call layout `match`
//! inside [`crate::tensor::prepared::PreparedStorage`] — block-granular
//! and branch-predicted.
//!
//! **Persistent engine state.** An [`EngineState`] owns what must survive
//! across passes without reallocation: the per-worker [`Scratch`] pool and
//! the rank-padded copies of the `C` tables and the current mode's core
//! matrix that the R-blocked kernels stream (`linalg::simd` documents why
//! the padded copies are bit-transparent). `Session` holds one for its
//! whole lifetime; the free-standing epoch wrappers create a throwaway.
//!
//! Every public epoch entry point in [`super::fastucker`] and
//! [`super::fastertucker`] is a one-line instantiation of [`run_epoch`];
//! `tests/engine_parity.rs` proves each instantiation bit-identical to the
//! pre-engine reference loops on one worker.

use crate::config::{SchedMode, TrainConfig};
use crate::linalg::simd::{
    pad_matrix_into, pad_r, prefetch_read_f32, prefetch_read_u32,
};
use crate::linalg::{Matrix, NodeReplicated};
use crate::model::ModelState;
use crate::sched::pool::WorkerStats;
use crate::sched::racy::RacyMatrix;
use crate::sched::shard::ShardPlan;
use crate::sched::topo::{self, WorkerHome};
use crate::util::bitset::DirtyRows;
use crate::util::timer::Timer;
use std::sync::Mutex;

use super::kernels::{
    accumulate_core_grad, apply_core_grad, chain_v_from_tables, chain_v_on_the_fly,
    chain_v_prefix_cached, effective_tile_nnz, fiber_w, Scratch,
};

/// How the coordinator refreshes `C^(n)` after a mode update (in-crate GEMM
/// or the AOT/PJRT kernel — injected so the engine stays backend-agnostic).
pub type RefreshC<'a> = dyn Fn(&mut ModelState, usize) + 'a;

/// Default refresh: in-crate GEMM.
pub fn refresh_rust(model: &mut ModelState, n: usize) {
    model.refresh_c(n);
}

/// No-op refresh — for algorithms that keep no `C` tables during training
/// (the FastTucker baseline syncs them once per epoch in the coordinator).
pub fn refresh_none(_model: &mut ModelState, _n: usize) {}

/// Where the chain scalars `v_r = Π_{m≠n} a_{i_m}·b^{(m)}_{:,r}` come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChainStrategy {
    /// Recompute every `a·b` dot product per visited group — the FastTucker
    /// baseline's `(N−1)·J·R` multiplications per non-zero.
    OnTheFly,
    /// Read the precomputed `C^(n) = A^(n) B^(n)` tables per visited group.
    Tables,
    /// `Tables`, plus Algorithm-4 prefix-product reuse across consecutive
    /// fibers of a block (only meaningful for fiber-ordered storage).
    TablesPrefixCached,
}

impl ChainStrategy {
    /// The chain source each FastTucker-family algorithm uses — one half of
    /// the `(storage, chain)` instantiation that
    /// [`crate::tensor::prepared::PreparedStorage`] builds exactly once per
    /// session. `None` for the full-core baselines, which do not run on the
    /// engine.
    ///
    /// # Examples
    ///
    /// ```
    /// use fastertucker::algo::{engine::ChainStrategy, Algo};
    ///
    /// assert_eq!(
    ///     ChainStrategy::for_algo(Algo::FasterTucker),
    ///     Some(ChainStrategy::TablesPrefixCached)
    /// );
    /// assert_eq!(ChainStrategy::for_algo(Algo::CuTucker), None);
    /// ```
    pub fn for_algo(algo: super::Algo) -> Option<ChainStrategy> {
        use super::Algo;
        match algo {
            Algo::FastTucker => Some(ChainStrategy::OnTheFly),
            Algo::FasterTuckerCoo | Algo::FasterTuckerBcsf => {
                Some(ChainStrategy::Tables)
            }
            Algo::FasterTucker => Some(ChainStrategy::TablesPrefixCached),
            Algo::CuTucker | Algo::PTucker => None,
        }
    }

    /// Whether the chain reads the precomputed `C` tables (and the engine
    /// must therefore keep its rank-padded table copies in sync).
    pub fn uses_tables(self) -> bool {
        matches!(self, ChainStrategy::Tables | ChainStrategy::TablesPrefixCached)
    }
}

/// Which model component an epoch pass updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateKind {
    /// SGD on the mode's factor matrix `A^(n)` (Hogwild row updates).
    Factor,
    /// Full-batch gradient on the mode's core matrix `B^(n)`.
    Core,
}

/// Receives the element stream of one storage block during an epoch pass.
///
/// The contract mirrors the paper's kernel structure: [`BlockSink::group`]
/// delivers the shared (non-update-mode) coordinates once per fiber — or
/// once per element for storages without sharing — and
/// [`BlockSink::leaves`] delivers the current group's non-zeros as
/// contiguous `(update-mode rows, values)` slice pairs. A group may stream
/// several leaf runs (B-CSF sub-fibers of one fiber); a run is never empty
/// and never spans groups.
pub trait BlockSink {
    /// A new shared-coordinate group. `coords[k]` pairs with the storage's
    /// [`SparseStorage::chain_modes`] entry `k`.
    fn group(&mut self, coords: &[u32]);
    /// One contiguous run of the current group's non-zeros:
    /// `(rows[k], vals[k])` is one non-zero at update-mode row `rows[k]`.
    fn leaves(&mut self, rows: &[u32], vals: &[f32]);
}

/// A sparse-tensor layout the engine can run an epoch over.
///
/// Implementations stream *blocks* — the schedulable work units a worker
/// claims — and, within a block, groups of non-zeros that share their
/// non-update-mode coordinates, each followed by its leaf runs as slices.
/// `drive_block` is generic over the sink, so every storage × sink pair
/// monomorphizes; the trait is deliberately **not** object-safe.
pub trait SparseStorage: Sync {
    /// Schedulable block count for the mode-`n` pass.
    fn num_blocks(&self, n: usize) -> usize;
    /// Non-zero count seen by the mode-`n` pass (core-gradient normalizer).
    fn nnz(&self, n: usize) -> usize;
    /// Non-zeros inside block `b` of the mode-`n` pass — the measured
    /// weight `ShardPlan` packs by (LPT) and charges to the claiming
    /// worker's [`WorkerStats`].
    fn block_weight(&self, n: usize, b: usize) -> usize;
    /// The non-update modes, in the order their coordinates are handed to
    /// [`BlockSink::group`] (ascending for COO, CSF tree order for B-CSF).
    /// Borrowed from the storage — never allocated per pass.
    fn chain_modes(&self, n: usize) -> &[usize];
    /// Stream block `b` of the mode-`n` pass into `sink`.
    fn drive_block<S: BlockSink>(&self, n: usize, b: usize, sink: &mut S);
}

/// What one epoch pass updates per visited non-zero. `visit` runs in the
/// hot loop with `v`/`w` already computed in the scratch; `visit_leaves`
/// consumes a whole contiguous run (override only to specialize the loop);
/// `merge` folds a finished worker's scratch accumulator into another's.
pub trait UpdateTarget: Sync {
    /// Apply one non-zero `x` at update-mode row `row` (chain products and
    /// the shared intermediate already live in the scratch).
    fn visit(&self, s: &mut Scratch, row: usize, x: f32);
    /// Consume a whole contiguous leaf run (default: per-element `visit`).
    #[inline]
    fn visit_leaves(&self, s: &mut Scratch, rows: &[u32], vals: &[f32]) {
        debug_assert_eq!(rows.len(), vals.len());
        for (&i, &x) in rows.iter().zip(vals.iter()) {
            self.visit(s, i as usize, x);
        }
    }
    /// Fold a finished worker's scratch accumulator into another's.
    fn merge(&self, acc: &mut Scratch, other: &Scratch);
}

/// Hogwild factor-row SGD: `a ← (1−γλ)a + γe·w` (paper eq. 10).
pub struct FactorTarget<'a> {
    /// Lock-free view over the mode's factor matrix.
    pub racy: &'a RacyMatrix<'a>,
    /// Regularization scale `1 − γ_A λ_A` applied to the existing row.
    pub scale: f32,
    /// Factor learning rate `γ_A`.
    pub lr: f32,
}

impl UpdateTarget for FactorTarget<'_> {
    #[inline]
    fn visit(&self, s: &mut Scratch, row: usize, x: f32) {
        let e = x - self.racy.row_dot(row, &s.w);
        self.racy.row_sgd_update(row, self.scale, self.lr * e, &s.w);
        // record the touched row in this worker's private bitset (one OR;
        // the sets merge into the model's per-mode dirty set at pass end)
        s.dirty.mark(row);
    }
    fn merge(&self, _acc: &mut Scratch, _other: &Scratch) {}
}

/// Per-worker core-gradient accumulation: `G[:,r] += e·v_r·a` (paper
/// eq. 11), merged across workers after the pass.
pub struct CoreTarget<'a> {
    /// The update mode's factor matrix `A^(n)` (read-only during the pass).
    pub factor_n: &'a Matrix,
}

impl UpdateTarget for CoreTarget<'_> {
    #[inline]
    fn visit(&self, s: &mut Scratch, row: usize, x: f32) {
        let a = self.factor_n.row(row);
        let Scratch { v, w, grad, .. } = s;
        let xhat = crate::linalg::dot(a, w);
        accumulate_core_grad(grad, x - xhat, v, a);
    }
    fn merge(&self, acc: &mut Scratch, other: &Scratch) {
        for (g, o) in acc.grad.data_mut().iter_mut().zip(other.grad.data()) {
            *g += o;
        }
    }
}

/// Chain source with the borrows resolved for one mode pass: the engine's
/// rank-padded table copies for the table-driven chains, the live model
/// matrices for the on-the-fly baseline.
#[derive(Clone, Copy)]
enum ChainSource<'a> {
    OnTheFly { factors: &'a [Matrix], cores: &'a [Matrix] },
    Tables(&'a [Matrix]),
    Cached(&'a [Matrix]),
}

/// Persistent, reallocation-free state the engine threads through passes:
/// the per-worker [`Scratch`] pool and the rank-padded kernel operands.
/// One per `Session` (`coordinator`); the free-standing epoch wrappers
/// create a throwaway. Buffers are lazily sized on first use and reused
/// verbatim afterwards — `tests/hotpath_alloc.rs` pins the no-allocation
/// guarantee with a counting allocator.
/// **Caching contract:** a state belongs to one `(model, storage, cfg)`
/// triple — exactly how `Session` owns it. The padded `C` copies are
/// resynced in full on first use and then kept fresh by the per-mode
/// refresh hook; a caller that mutates `model.c_tables` *outside* the
/// engine (none in-tree does) must call [`EngineState::invalidate_tables`]
/// first. The cached per-mode plans rekey on `(workers, num_blocks)` and
/// rebuild automatically when either changes.
pub struct EngineState {
    /// Idle scratches, pooled **per home node** (`pool[node]`) so a
    /// worker's buffers are first-touched — and stay — on its node.
    /// Single-node runs keep exactly one pool, the pre-NUMA behavior.
    /// A shape change simply drops the stale buffers.
    pool: Mutex<Vec<Vec<Scratch>>>,
    /// Rank-padded copies of `C^(m)` (table-driven chains only), resynced
    /// after each mode's refresh. Node-replicated: each worker reads its
    /// home node's bitwise-identical mirror instead of streaming node 0's
    /// copy across the interconnect.
    padded_c: NodeReplicated<Vec<Matrix>>,
    /// Whether `padded_c` mirrors the model's tables (set by the first
    /// full sync, maintained by the per-mode refresh resync).
    tables_synced: bool,
    /// Rank-padded copy of the current mode's core `B^(n)`,
    /// node-replicated like the tables.
    padded_core: NodeReplicated<Matrix>,
    /// Per-worker memory-hierarchy homes the passes spawn with (see
    /// [`EngineState::set_worker_homes`]). Empty — or a stale length —
    /// runs the unhomed single-node path, bit-for-bit.
    worker_homes: Vec<WorkerHome>,
    /// Snapshot of the mode's dirty rows taken at the pass-end merge
    /// point, *before* the refresh hook consumes the model's set — keys
    /// the dirty-64-row-block mirror resync in `sync_table`.
    sync_dirty: DirtyRows,
    /// Steals that crossed a node boundary (stealing scheduler with
    /// homes only) — the migration price of dynamic rebalancing.
    cross_node_steals: u64,
    /// Per-mode shard plans — block weights and LPT order are immutable
    /// per storage, so the weight collection + sort happen once per
    /// session, not once per pass.
    plans: Vec<ShardPlan>,
    /// Per-mode steal-queue seeds (derived from the plan, cached so the
    /// stealing path allocates nothing per pass). Empty until a stealing
    /// pass first runs the mode; cleared whenever the plan rebuilds.
    queues: Vec<Vec<Vec<u32>>>,
    /// Storage generation the cached plans were built against. Bumped via
    /// [`EngineState::set_storage_epoch`] whenever `PreparedStorage` is
    /// rebuilt (evict→rebuild, delta re-staging) so a stale plan can never
    /// index a rebuilt block list — even one that happens to keep the same
    /// block count with different weights.
    storage_epoch: u64,
    /// Flat per-block core-gradient slots for the stealing core pass
    /// (`num_blocks × j·r`, grown once, reused verbatim). Unused (empty)
    /// under `SchedMode::Static`.
    grad_slots: Vec<f32>,
    /// Seconds spent inside the refresh hook since the last
    /// [`EngineState::take_refresh_seconds`] — the session drains this
    /// after each pass into `PrepStats::refresh_seconds` (Table V keeps
    /// refresh separate from both staging and sweep).
    refresh_seconds: f64,
}

impl Default for EngineState {
    fn default() -> Self {
        EngineState {
            pool: Mutex::new(vec![Vec::new()]),
            padded_c: NodeReplicated::new(Vec::new()),
            tables_synced: false,
            padded_core: NodeReplicated::new(Matrix::zeros(0, 0)),
            worker_homes: Vec::new(),
            sync_dirty: DirtyRows::new(),
            cross_node_steals: 0,
            plans: Vec::new(),
            queues: Vec::new(),
            storage_epoch: 0,
            grad_slots: Vec::new(),
            refresh_seconds: 0.0,
        }
    }
}

impl EngineState {
    /// Empty state; buffers are sized lazily on first use.
    pub fn new() -> EngineState {
        EngineState::default()
    }

    /// Drain the seconds spent in the refresh hook since the last call.
    pub fn take_refresh_seconds(&mut self) -> f64 {
        std::mem::take(&mut self.refresh_seconds)
    }

    /// Provision the per-node machinery for the given worker homes (from
    /// the session's lease, or a synthetic topology in tests): operand
    /// mirrors for every home node plus a scratch pool per node. Workers
    /// bind to `homes[w]` at spawn and index their node's replica. Empty
    /// homes — or homes on a single node — degenerate to the pre-NUMA
    /// path: no mirrors, one pool, no binding. Homes whose length does
    /// not match the pass's worker count are ignored for that pass.
    pub fn set_worker_homes(&mut self, homes: Vec<WorkerHome>) {
        let nodes = homes.iter().map(|h| h.node + 1).max().unwrap_or(1);
        self.padded_c.set_nodes(nodes);
        self.padded_core.set_nodes(nodes);
        {
            let mut pools = self.pool.lock().unwrap();
            if pools.len() < nodes {
                pools.resize_with(nodes, Vec::new);
            }
        }
        self.worker_homes = homes;
    }

    /// The homes the next pass will spawn its workers with.
    pub fn worker_homes(&self) -> &[WorkerHome] {
        &self.worker_homes
    }

    /// Drain the cross-node steal count accumulated since the last call.
    pub fn take_cross_node_steals(&mut self) -> u64 {
        std::mem::take(&mut self.cross_node_steals)
    }

    /// Force a full padded-table resync on the next pass. Only needed
    /// after mutating `model.c_tables` outside the engine's refresh hook.
    pub fn invalidate_tables(&mut self) {
        self.tables_synced = false;
    }

    /// Pin the cached plans to a storage generation. A changed epoch drops
    /// every cached plan (and steal-queue seed) so the next pass rebuilds
    /// them against the rebuilt storage — the `Session` passes its
    /// `PrepStats::builds` counter here after `ensure_prepared`, which
    /// covers both evict→rebuild and delta re-staging.
    pub fn set_storage_epoch(&mut self, epoch: u64) {
        if self.storage_epoch != epoch {
            self.storage_epoch = epoch;
            self.plans.clear();
            self.queues.clear();
        }
    }

    /// The storage generation the cached plans were built against (tests).
    pub fn storage_epoch(&self) -> u64 {
        self.storage_epoch
    }

    /// Cached plan block counts per mode (tests: proves plans were rebuilt
    /// rather than reused across a storage rebuild).
    pub fn plan_block_counts(&self) -> Vec<usize> {
        self.plans.iter().map(|p| p.num_blocks).collect()
    }

    /// Full sync on first use (or after invalidation / a shape change);
    /// no-op afterwards — the per-mode [`Self::sync_table`] after each
    /// refresh keeps the copies fresh within and across passes.
    fn ensure_tables(&mut self, tables: &[Matrix]) {
        let prim = self.padded_c.primary();
        let shape_ok = prim.len() == tables.len()
            && prim
                .iter()
                .zip(tables.iter())
                .all(|(p, t)| p.rows() == t.rows() && p.cols() == pad_r(t.cols()));
        if self.tables_synced && shape_ok {
            return;
        }
        let prim = self.padded_c.primary_mut();
        prim.resize_with(tables.len(), || Matrix::zeros(0, 0));
        for (dst, src) in prim.iter_mut().zip(tables.iter()) {
            pad_matrix_into(dst, src);
        }
        // first (or shape-changing) sync: every mirror takes a full copy
        self.padded_c.sync_with(|p, m| copy_tables_into(m, p));
        self.tables_synced = true;
    }

    /// Resync the rank-padded copy of `C^(n)` after the mode's refresh.
    /// The primary is re-padded in full (the pre-NUMA behavior); each
    /// mirror then receives only the 64-row blocks recorded dirty at the
    /// pass-end merge point ([`Self::snapshot_sync_dirty`]) — falling
    /// back to a full copy when the whole table was invalidated or the
    /// shape changed. Either way the mirrors end byte-identical to the
    /// primary, so which replica a worker reads can never change the
    /// math.
    fn sync_table(&mut self, n: usize, table: &Matrix) {
        let dirty = std::mem::take(&mut self.sync_dirty);
        pad_matrix_into(&mut self.padded_c.primary_mut()[n], table);
        self.padded_c.sync_with(|p, m| {
            if m.len() != p.len() {
                copy_tables_into(m, p);
                return;
            }
            let (src, dst) = (&p[n], &mut m[n]);
            if dirty.is_all() || dst.rows() != src.rows() || dst.cols() != src.cols()
            {
                copy_matrix_into(dst, src);
                return;
            }
            let (rows, pc) = (src.rows(), src.cols());
            for w in 0..crate::util::ceil_div(rows, 64) {
                if !dirty.word_dirty(w) {
                    continue;
                }
                // word w covers exactly the rows [64w, 64w+64): one
                // contiguous row-major range in both replicas
                let lo = w * 64 * pc;
                let hi = ((w + 1) * 64).min(rows) * pc;
                dst.data_mut()[lo..hi].copy_from_slice(&src.data()[lo..hi]);
            }
        });
        self.sync_dirty = dirty;
    }

    /// Record which rows the upcoming refresh may rewrite — called at the
    /// pass-end merge point, *before* the refresh hook consumes the
    /// model's dirty set. A superset is merely conservative (the mirrors
    /// over-copy but stay coherent).
    fn snapshot_sync_dirty(&mut self, src: &DirtyRows) {
        self.sync_dirty.clear();
        self.sync_dirty.merge_from(src);
    }

    /// Build (or reuse) the mode-`n` shard plan: measured per-block nnz
    /// weights, LPT order for >1 worker. Rebuilt only when the worker
    /// count or block count changes (or the whole cache was dropped by
    /// [`Self::set_storage_epoch`]). When `stealing`, the per-worker
    /// steal-queue seed is derived and cached alongside the plan.
    fn ensure_plan<St: SparseStorage>(
        &mut self,
        workers: usize,
        storage: &St,
        n: usize,
        stealing: bool,
    ) {
        if self.plans.len() <= n {
            self.plans.resize_with(n + 1, || ShardPlan::new(1, 0));
        }
        if self.queues.len() <= n {
            self.queues.resize_with(n + 1, Vec::new);
        }
        let nb = storage.num_blocks(n);
        let cur = &self.plans[n];
        let plan_ok = cur.weighted() && cur.workers == workers && cur.num_blocks == nb;
        if !plan_ok {
            let weights: Vec<u32> = (0..nb)
                .map(|b| storage.block_weight(n, b).min(u32::MAX as usize) as u32)
                .collect();
            self.plans[n] = ShardPlan::lpt(workers, weights);
            self.queues[n].clear();
        }
        if stealing && self.queues[n].len() != self.plans[n].workers {
            self.queues[n] = self.plans[n].steal_queues();
        }
    }

    fn set_core(&mut self, core: &Matrix) {
        pad_matrix_into(self.padded_core.primary_mut(), core);
        // the padded core is small (J × pad_r(R)): mirrors take a full
        // copy every mode, reusing their allocations
        self.padded_core.sync_with(|p, m| copy_matrix_into(m, p));
    }

    fn resolve_chain<'a>(
        &'a self,
        chain: ChainStrategy,
        model: &'a ModelState,
        node: usize,
    ) -> ChainSource<'a> {
        match chain {
            ChainStrategy::OnTheFly => ChainSource::OnTheFly {
                factors: &model.factors,
                cores: &model.cores,
            },
            ChainStrategy::Tables => ChainSource::Tables(self.padded_c.get(node)),
            ChainStrategy::TablesPrefixCached => {
                ChainSource::Cached(self.padded_c.get(node))
            }
        }
    }

    /// Take a scratch from `node`'s pool (or build one on first use /
    /// shape change — inside the worker thread, so the buffers
    /// first-touch on the worker's home node). Core passes zero the
    /// gradient accumulator; both kinds invalidate the prefix cache —
    /// everything else is overwritten before it is read. Unprovisioned
    /// nodes clamp to the last pool (single-node: pool 0, the pre-NUMA
    /// behavior).
    fn checkout(
        &self,
        node: usize,
        order: usize,
        j: usize,
        r: usize,
        zero_grad: bool,
    ) -> Scratch {
        let reused = {
            let mut pools = self.pool.lock().unwrap();
            let idx = node.min(pools.len().saturating_sub(1));
            pools.get_mut(idx).and_then(|p| p.pop())
        };
        let mut s = match reused {
            Some(s) if s.fits(order, j, r) => s,
            _ => Scratch::new(order, j, r),
        };
        if zero_grad {
            s.grad.fill(0.0);
        }
        s.reset_prefix();
        s
    }

    fn put_back(&self, s: Scratch, node: usize) {
        let mut pools = self.pool.lock().unwrap();
        let idx = node.min(pools.len().saturating_sub(1));
        pools[idx].push(s);
    }
}

/// Byte-copy `src` into `dst`, reusing `dst`'s allocation when the shapes
/// already match (the steady-state mirror resync allocates nothing).
fn copy_matrix_into(dst: &mut Matrix, src: &Matrix) {
    if dst.rows() != src.rows() || dst.cols() != src.cols() {
        *dst = Matrix::zeros(src.rows(), src.cols());
    }
    dst.data_mut().copy_from_slice(src.data());
}

/// [`copy_matrix_into`] over a whole table list (full mirror resync).
fn copy_tables_into(dst: &mut Vec<Matrix>, src: &[Matrix]) {
    dst.resize_with(src.len(), || Matrix::zeros(0, 0));
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        copy_matrix_into(d, s);
    }
}

/// Disjoint per-block gradient slots for the stealing core pass. Each block
/// is claimed by **exactly one** worker (`parallel_reduce_stealing`'s
/// contract), so `publish(b, ..)` writes a `stride`-sized region no other
/// thread touches — that exactly-once claim discipline is what makes the
/// `Sync` impl sound. After the pass the slots are folded in ascending
/// block id, which is why the merged gradient's bits are independent of
/// which worker ran which block.
struct GradSlots<'a> {
    data: *mut f32,
    len: usize,
    _buf: std::marker::PhantomData<&'a mut [f32]>,
}

// Safety: workers write disjoint `stride`-sized regions (one block = one
// claimer), and the buffer outlives the scoped threads.
unsafe impl Sync for GradSlots<'_> {}

impl<'a> GradSlots<'a> {
    fn new(buf: &'a mut [f32]) -> GradSlots<'a> {
        GradSlots {
            data: buf.as_mut_ptr(),
            len: buf.len(),
            _buf: std::marker::PhantomData,
        }
    }

    /// Copy one finished block's partial gradient into its canonical slot.
    ///
    /// # Safety
    /// Block `b` must be claimed by exactly one worker for the duration of
    /// the pass (no two threads may publish the same `b`).
    unsafe fn publish(&self, b: usize, stride: usize, src: &[f32]) {
        debug_assert_eq!(src.len(), stride);
        debug_assert!((b + 1) * stride <= self.len);
        std::ptr::copy_nonoverlapping(src.as_ptr(), self.data.add(b * stride), stride);
    }
}

/// The per-worker state threaded through a block stream: chain inputs, the
/// mode's (rank-padded) core matrix, the update target, and the scratch
/// buffers.
struct EngineSink<'a, T: UpdateTarget> {
    chain: ChainSource<'a>,
    modes: &'a [usize],
    core_n: &'a Matrix,
    target: &'a T,
    /// Leaf-run tile size in non-zeros ([`effective_tile_nnz`]): long
    /// runs are consumed in L2-sized chunks with the next chunk's
    /// operands prefetched — chunking the existing iteration order, so
    /// any tile size is bitwise-identical to the untiled sweep.
    tile: usize,
    /// Home node this sink's scratch was checked out from (and whose
    /// operand replicas `chain`/`core_n` point into).
    node: usize,
    s: Scratch,
}

impl<T: UpdateTarget> EngineSink<'_, T> {
    /// Block boundary: invalidate the fiber prefix cache (a new block's
    /// first fiber has no guaranteed relation to the previous one).
    fn begin_block(&mut self) {
        self.s.reset_prefix();
    }
}

/// Issue the fiber's `C`-row prefetches up front so the chain kernel's
/// dependent row reads overlap the line fills instead of serializing on
/// them. A pure hint — no architectural effect (see `linalg::simd`).
#[inline]
fn prefetch_chain_rows(c: &[Matrix], modes: &[usize], coords: &[u32]) {
    for (&m, &cc) in modes.iter().zip(coords.iter()) {
        prefetch_read_f32(c[m].row(cc as usize));
    }
}

impl<T: UpdateTarget> BlockSink for EngineSink<'_, T> {
    #[inline]
    fn group(&mut self, coords: &[u32]) {
        match self.chain {
            ChainSource::Tables(c) => {
                prefetch_chain_rows(c, self.modes, coords);
                chain_v_from_tables(c, self.modes, coords, &mut self.s.v)
            }
            ChainSource::Cached(c) => {
                prefetch_chain_rows(c, self.modes, coords);
                chain_v_prefix_cached(c, self.modes, coords, &mut self.s)
            }
            ChainSource::OnTheFly { factors, cores } => {
                chain_v_on_the_fly(factors, cores, self.modes, coords, &mut self.s.v)
            }
        }
        fiber_w(self.core_n, &self.s.v, &mut self.s.w);
    }

    #[inline]
    fn leaves(&mut self, rows: &[u32], vals: &[f32]) {
        let tile = self.tile;
        if rows.len() <= tile {
            self.target.visit_leaves(&mut self.s, rows, vals);
            return;
        }
        // Walk the run in L2-sized tiles, hinting the next tile's indices
        // and values into cache while the current one computes. Both
        // update targets consume leaves element-by-element in order, so
        // the chunk boundaries are bitwise-invisible.
        let mut lo = 0;
        while lo < rows.len() {
            let hi = (lo + tile).min(rows.len());
            if hi < rows.len() {
                prefetch_read_u32(&rows[hi..]);
                prefetch_read_f32(&vals[hi..]);
            }
            self.target.visit_leaves(&mut self.s, &rows[lo..hi], &vals[lo..hi]);
            lo = hi;
        }
    }
}

/// One full epoch of `kind` updates over `storage` with a throwaway
/// [`EngineState`]: all modes in turn, refreshing `C^(n)` through `refresh`
/// after each mode. Returns the accumulated per-worker scheduling stats.
pub fn run_epoch<St: SparseStorage>(
    model: &mut ModelState,
    storage: &St,
    chain: ChainStrategy,
    kind: UpdateKind,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) -> WorkerStats {
    let mut state = EngineState::new();
    run_epoch_with(model, storage, chain, kind, cfg, refresh, &mut state)
}

/// [`run_epoch`] over a caller-owned [`EngineState`] — the `Session` path,
/// where scratch buffers and padded operands persist across epochs.
pub fn run_epoch_with<St: SparseStorage>(
    model: &mut ModelState,
    storage: &St,
    chain: ChainStrategy,
    kind: UpdateKind,
    cfg: &TrainConfig,
    refresh: &RefreshC,
    state: &mut EngineState,
) -> WorkerStats {
    match kind {
        UpdateKind::Factor => factor_epoch_with(model, storage, chain, cfg, refresh, state),
        UpdateKind::Core => core_epoch_with(model, storage, chain, cfg, refresh, state),
    }
}

/// One factor-update epoch (paper Algorithms 2/4) with a throwaway state.
pub fn factor_epoch<St: SparseStorage>(
    model: &mut ModelState,
    storage: &St,
    chain: ChainStrategy,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) -> WorkerStats {
    let mut state = EngineState::new();
    factor_epoch_with(model, storage, chain, cfg, refresh, &mut state)
}

/// One factor-update epoch: for each mode, take `A^(n)` out for Hogwild
/// writes, stream every block, reinstate, refresh.
pub fn factor_epoch_with<St: SparseStorage>(
    model: &mut ModelState,
    storage: &St,
    chain: ChainStrategy,
    cfg: &TrainConfig,
    refresh: &RefreshC,
    state: &mut EngineState,
) -> WorkerStats {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let stealing = cfg.sched == SchedMode::Stealing;
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;
    let tile = effective_tile_nnz(cfg.tile_nnz, j, r);
    let mut total = WorkerStats::with_workers(workers);
    let needs_tables = chain.uses_tables();
    if needs_tables {
        state.ensure_tables(&model.c_tables);
    }

    for n in 0..order {
        state.set_core(&model.cores[n]);
        state.ensure_plan(workers, storage, n, stealing);
        let modes = storage.chain_modes(n);
        let rows_n = model.factors[n].rows();
        let mut target_m =
            std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        let (mut pass_s, pass_node, cross) = {
            let racy = RacyMatrix::new(&mut target_m);
            let tgt = FactorTarget { racy: &racy, scale, lr: cfg.lr_a };
            let st: &EngineState = &*state;
            let model_ref: &ModelState = &*model;
            let plan = &st.plans[n];
            let homes: &[WorkerHome] = if st.worker_homes.len() == workers {
                &st.worker_homes
            } else {
                &[]
            };
            let init = || {
                // resolved *inside* the worker thread, after bind_worker:
                // the sink reads its home node's operand replicas and
                // checks its scratch out of the node's pool (first-touch
                // lands the buffers on the right node)
                let node = topo::current_node();
                let mut s = st.checkout(node, order, j, r, false);
                s.dirty.ensure(rows_n);
                EngineSink {
                    chain: st.resolve_chain(chain, model_ref, node),
                    modes,
                    core_n: st.padded_core.get(node),
                    target: &tgt,
                    tile,
                    node,
                    s,
                }
            };
            let step = |sink: &mut EngineSink<'_, FactorTarget<'_>>,
                        _w: usize,
                        b: usize| {
                sink.begin_block();
                storage.drive_block(n, b, sink);
            };
            // Hogwild rows land in the shared matrix directly and the
            // dirty bitsets union commutatively, so the factor merge is
            // schedule-independent under either scheduler.
            let merge = |acc: &mut EngineSink<'_, FactorTarget<'_>>,
                         other: EngineSink<'_, FactorTarget<'_>>| {
                let EngineSink { s: mut other_s, node: other_node, .. } = other;
                tgt.merge(&mut acc.s, &other_s);
                // fold the worker's touched rows into the surviving
                // scratch so the pass ends with one union set
                acc.s.dirty.merge_from(&other_s.dirty);
                other_s.dirty.clear();
                st.put_back(other_s, other_node);
            };
            let (sink, stats, cross) = if stealing {
                plan.execute_stealing_homed(&st.queues[n], homes, init, step, merge)
            } else {
                let (sink, stats) = plan.execute_homed(homes, init, step, merge);
                (sink, stats, 0)
            };
            total.absorb(&stats);
            (sink.s, sink.node, cross)
        };
        model.factors[n] = target_m;
        // dirty-set merge point: the union of every worker's marks lands
        // in the model *before* the refresh hook runs, so an incremental
        // refresh sees exactly the rows this pass touched
        model.dirty[n].merge_from(&pass_s.dirty);
        pass_s.dirty.clear();
        state.put_back(pass_s, pass_node);
        state.cross_node_steals += cross as u64;
        if needs_tables {
            // snapshot before the refresh hook consumes the dirty set:
            // the mirror resync after the refresh copies exactly these
            // 64-row blocks
            state.snapshot_sync_dirty(&model.dirty[n]);
        }
        let t = Timer::start();
        refresh(model, n);
        state.refresh_seconds += t.seconds();
        if needs_tables {
            state.sync_table(n, &model.c_tables[n]);
        }
    }
    total
}

/// One core-update epoch (paper Algorithms 3/5) with a throwaway state.
pub fn core_epoch<St: SparseStorage>(
    model: &mut ModelState,
    storage: &St,
    chain: ChainStrategy,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) -> WorkerStats {
    let mut state = EngineState::new();
    core_epoch_with(model, storage, chain, cfg, refresh, &mut state)
}

/// One core-update epoch: for each mode, accumulate the full-batch gradient
/// of `B^(n)` per worker, merge, apply once, refresh.
pub fn core_epoch_with<St: SparseStorage>(
    model: &mut ModelState,
    storage: &St,
    chain: ChainStrategy,
    cfg: &TrainConfig,
    refresh: &RefreshC,
    state: &mut EngineState,
) -> WorkerStats {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let stealing = cfg.sched == SchedMode::Stealing;
    let stride = j * r;
    let tile = effective_tile_nnz(cfg.tile_nnz, j, r);
    let mut total = WorkerStats::with_workers(workers);
    let needs_tables = chain.uses_tables();
    if needs_tables {
        state.ensure_tables(&model.c_tables);
    }

    for n in 0..order {
        state.set_core(&model.cores[n]);
        state.ensure_plan(workers, storage, n, stealing);
        let modes = storage.chain_modes(n);
        let nnz = storage.nnz(n);
        if stealing {
            let want = state.plans[n].num_blocks * stride;
            if state.grad_slots.len() < want {
                state.grad_slots.resize(want, 0.0);
            }
        }
        // lift the slot buffer out so the state can be shared immutably
        // across the pass's workers; restored (same allocation) after
        let mut slots = std::mem::take(&mut state.grad_slots);
        let (acc_s, acc_node, cross, stats) = {
            let st: &EngineState = &*state;
            let plan = &st.plans[n];
            let model_ref: &ModelState = &*model;
            let homes: &[WorkerHome] = if st.worker_homes.len() == workers {
                &st.worker_homes
            } else {
                &[]
            };
            let tgt = CoreTarget { factor_n: &model.factors[n] };
            let init = || {
                // per-worker resolution, as in the factor pass: home
                // node's replicas, home node's scratch pool
                let node = topo::current_node();
                EngineSink {
                    chain: st.resolve_chain(chain, model_ref, node),
                    modes,
                    core_n: st.padded_core.get(node),
                    target: &tgt,
                    tile,
                    node,
                    s: st.checkout(node, order, j, r, true),
                }
            };
            if stealing {
                // Canonical-merge-order discipline: every block's partial
                // gradient is computed against a zeroed accumulator and
                // published to its own slot; the slots are folded in
                // ascending block id below. The folded bits therefore
                // depend only on the block list — not on which worker ran
                // which block, how many workers ran, or what was stolen.
                let nb = plan.num_blocks;
                for x in slots[..nb * stride].iter_mut() {
                    *x = 0.0;
                }
                let slot_cell = GradSlots::new(&mut slots);
                let (sink, stats, cross) = plan.execute_stealing_homed(
                    &st.queues[n],
                    homes,
                    init,
                    |sink, _w, b| {
                        sink.s.grad.fill(0.0);
                        sink.begin_block();
                        storage.drive_block(n, b, sink);
                        // Safety: the stealing substrate claims each block
                        // exactly once, so slot `b` has one writer.
                        unsafe { slot_cell.publish(b, stride, sink.s.grad.data()) };
                    },
                    |_acc, other| {
                        // partials already live in the slots; the worker
                        // scratches just go back to their node's pool
                        let EngineSink { s: other_s, node: other_node, .. } =
                            other;
                        st.put_back(other_s, other_node);
                    },
                );
                let mut acc_s = sink.s;
                acc_s.grad.fill(0.0);
                let g = acc_s.grad.data_mut();
                for b in 0..nb {
                    let slot = &slots[b * stride..(b + 1) * stride];
                    for (gi, si) in g.iter_mut().zip(slot.iter()) {
                        *gi += si;
                    }
                }
                (acc_s, sink.node, cross, stats)
            } else {
                let (sink, stats) = plan.execute_homed(
                    homes,
                    init,
                    |sink, _w, b| {
                        sink.begin_block();
                        storage.drive_block(n, b, sink);
                    },
                    |acc, other| {
                        let EngineSink { s: other_s, node: other_node, .. } =
                            other;
                        tgt.merge(&mut acc.s, &other_s);
                        st.put_back(other_s, other_node);
                    },
                );
                (sink.s, sink.node, 0, stats)
            }
        };
        state.grad_slots = slots;
        apply_core_grad(&mut model.cores[n], &acc_s.grad, nnz, cfg.lr_b, cfg.lambda_b);
        state.put_back(acc_s, acc_node);
        state.cross_node_steals += cross as u64;
        // a core change invalidates every row of C^(n): flag the whole
        // table so an incremental refresh falls back to the full path
        model.dirty[n].mark_all();
        if needs_tables {
            // all-dirty snapshot: the mirror resync takes the full-copy
            // fast path after the refresh
            state.snapshot_sync_dirty(&model.dirty[n]);
        }
        let t = Timer::start();
        refresh(model, n);
        state.refresh_seconds += t.seconds();
        if needs_tables {
            state.sync_table(n, &model.c_tables[n]);
        }
        total.absorb(&stats);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::tensor::bcsf::{BcsfPerElement, BcsfShared, BcsfTensor};
    use crate::tensor::coo::{CooBlocks, CooTensor};

    fn setup() -> (ModelState, CooTensor, TrainConfig) {
        let t = recommender(&RecommenderSpec::tiny(), 77);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 1,
            block_nnz: 256,
            fiber_threshold: 16,
            ..TrainConfig::default()
        };
        let model = ModelState::init(&cfg, 5);
        (model, t, cfg)
    }

    #[test]
    fn storage_contracts_agree_on_totals() {
        let (_, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let bcsf: Vec<BcsfTensor> = (0..3)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let shared = BcsfShared::new(&bcsf);
        let per_elem = BcsfPerElement::new(&bcsf);
        for n in 0..3 {
            assert_eq!(coo.nnz(n), t.nnz());
            assert_eq!(shared.nnz(n), per_elem.nnz(n));
            assert!(coo.num_blocks(n) > 0);
            assert!(shared.num_blocks(n) > 0);
            assert_eq!(coo.chain_modes(n).len(), 2);
            assert_eq!(shared.chain_modes(n).len(), 2);
            assert!(!coo.chain_modes(n).contains(&n));
            assert!(!shared.chain_modes(n).contains(&n));
        }
    }

    /// Every storage's per-block weights must tile its nnz exactly — the
    /// LPT packing and claimed-nnz accounting depend on it.
    #[test]
    fn block_weights_tile_nnz() {
        fn check<St: SparseStorage>(s: &St, order: usize, what: &str) {
            for n in 0..order {
                let total: usize =
                    (0..s.num_blocks(n)).map(|b| s.block_weight(n, b)).sum();
                assert_eq!(total, s.nnz(n), "{what} mode {n}");
            }
        }
        let (_, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let bcsf: Vec<BcsfTensor> = (0..3)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        check(&coo, 3, "coo");
        check(&BcsfShared::new(&bcsf), 3, "bcsf-shared");
        check(&BcsfPerElement::new(&bcsf), 3, "bcsf-per-element");
    }

    struct Counter {
        groups: usize,
        leaves: usize,
        runs: usize,
        value_sum: f64,
        group_open: bool,
    }

    impl BlockSink for Counter {
        fn group(&mut self, coords: &[u32]) {
            assert!(!coords.is_empty());
            self.groups += 1;
            self.group_open = true;
        }
        fn leaves(&mut self, rows: &[u32], vals: &[f32]) {
            assert!(self.group_open, "leaf run before any group");
            assert_eq!(rows.len(), vals.len());
            assert!(!rows.is_empty(), "empty leaf run");
            self.runs += 1;
            self.leaves += rows.len();
            self.value_sum += vals.iter().map(|&v| v as f64).sum::<f64>();
        }
    }

    fn count_stream<St: SparseStorage>(storage: &St, n: usize) -> Counter {
        let mut c = Counter {
            groups: 0,
            leaves: 0,
            runs: 0,
            value_sum: 0.0,
            group_open: false,
        };
        for b in 0..storage.num_blocks(n) {
            storage.drive_block(n, b, &mut c);
        }
        c
    }

    /// Every storage must stream each non-zero exactly once per mode pass,
    /// with a group announced before its leaf runs.
    #[test]
    fn storages_stream_every_nnz_once() {
        let (_, t, cfg) = setup();
        let exact_sum: f64 = t.values().iter().map(|&v| v as f64).sum();
        let bcsf: Vec<BcsfTensor> = (0..3)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let shared = BcsfShared::new(&bcsf);
        let per_elem = BcsfPerElement::new(&bcsf);
        for n in 0..3 {
            for (what, c) in [
                ("coo", count_stream(&coo, n)),
                ("bcsf-shared", count_stream(&shared, n)),
                ("bcsf-per-element", count_stream(&per_elem, n)),
            ] {
                let nnz = match what {
                    "coo" => coo.nnz(n),
                    _ => shared.nnz(n),
                };
                assert_eq!(c.leaves, nnz, "{what} mode {n}");
                assert!(c.groups >= 1 && c.groups <= c.leaves, "{what} mode {n}");
                assert!(c.runs >= c.groups, "{what} mode {n}");
                assert!(
                    (c.value_sum - exact_sum).abs() < 1e-3,
                    "{what} mode {n}: value sum drifted"
                );
            }
        }
    }

    /// The shared B-CSF stream must announce strictly fewer groups than
    /// leaves on a fiber-rich tensor (that is the whole point of sharing),
    /// while the per-element ablation announces exactly one per leaf.
    #[test]
    fn sharing_reduces_group_count() {
        let (_, t, cfg) = setup();
        let bcsf: Vec<BcsfTensor> = (0..3)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let shared = BcsfShared::new(&bcsf);
        let per_elem = BcsfPerElement::new(&bcsf);
        let mut any_shared_win = false;
        for n in 0..3 {
            let ts = count_stream(&shared, n);
            let tp = count_stream(&per_elem, n);
            assert_eq!(ts.leaves, tp.leaves);
            assert_eq!(tp.groups, tp.leaves);
            assert!(ts.groups <= tp.groups);
            if ts.groups < tp.groups {
                any_shared_win = true;
            }
        }
        assert!(any_shared_win, "no mode had any fiber with >1 leaf");
    }

    #[test]
    fn engine_factor_epoch_reduces_error_and_reports_stats() {
        let (mut model, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let (before, _) = crate::metrics::rmse_mae(&model, &t, 1);
        let mut stats = WorkerStats::with_workers(1);
        for _ in 0..3 {
            stats.absorb(&run_epoch(
                &mut model,
                &coo,
                ChainStrategy::Tables,
                UpdateKind::Factor,
                &cfg,
                &refresh_rust,
            ));
        }
        let (after, _) = crate::metrics::rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
        // 3 epochs × 3 modes × blocks-per-pass
        assert_eq!(stats.total_blocks(), 3 * 3 * coo.num_blocks(0));
        // every claimed non-zero is accounted to a worker
        assert_eq!(stats.total_nnz(), 3 * 3 * t.nnz());
    }

    #[test]
    fn engine_core_epoch_reduces_error() {
        let (mut model, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let (before, _) = crate::metrics::rmse_mae(&model, &t, 1);
        for _ in 0..5 {
            run_epoch(
                &mut model,
                &coo,
                ChainStrategy::Tables,
                UpdateKind::Core,
                &cfg,
                &refresh_rust,
            );
        }
        let (after, _) = crate::metrics::rmse_mae(&model, &t, 1);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    /// Dirty-row incremental refresh must be invisible to the math: whole
    /// interleaved factor/core epochs refreshed incrementally equal the
    /// same epochs with full per-mode recomputes, bit for bit.
    #[test]
    fn incremental_refresh_epochs_are_bitwise_full_refresh_epochs() {
        let (m0, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let mut m_full = m0.clone();
        let mut m_inc = m0;
        let mut st_full = EngineState::new();
        let mut st_inc = EngineState::new();
        let inc = |m: &mut ModelState, n: usize| m.refresh_c_dirty(n, None);
        for _ in 0..2 {
            for kind in [UpdateKind::Factor, UpdateKind::Core] {
                run_epoch_with(
                    &mut m_full,
                    &coo,
                    ChainStrategy::Tables,
                    kind,
                    &cfg,
                    &refresh_rust,
                    &mut st_full,
                );
                run_epoch_with(
                    &mut m_inc,
                    &coo,
                    ChainStrategy::Tables,
                    kind,
                    &cfg,
                    &inc,
                    &mut st_inc,
                );
            }
        }
        assert!(st_inc.take_refresh_seconds() > 0.0);
        for n in 0..3 {
            assert_eq!(m_inc.factors[n].max_abs_diff(&m_full.factors[n]), 0.0);
            assert_eq!(m_inc.cores[n].max_abs_diff(&m_full.cores[n]), 0.0);
            assert_eq!(m_inc.c_tables[n].max_abs_diff(&m_full.c_tables[n]), 0.0);
        }
    }

    /// `--sched stealing` on one worker must be bit-identical to the
    /// static path on one worker for factor passes: the steal-queue seed
    /// is the identity order there, so both drain the same serial block
    /// loop and apply the same Hogwild-free sequential updates. This
    /// anchors the stealing scheduler to every frozen parity reference.
    /// (Core passes are anchored separately: the stealing core pass folds
    /// per-block slots in canonical block order — a *different but
    /// worker-count-independent* f32 association than the static path's
    /// continuous accumulation, pinned by the cross-worker-count test
    /// below.)
    #[test]
    fn stealing_single_worker_factor_passes_match_static_bitwise() {
        let (m0, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let cfg_steal = TrainConfig { sched: crate::config::SchedMode::Stealing, ..cfg.clone() };
        let mut m_static = m0.clone();
        let mut m_steal = m0;
        let mut st_static = EngineState::new();
        let mut st_steal = EngineState::new();
        for _ in 0..3 {
            run_epoch_with(
                &mut m_static,
                &coo,
                ChainStrategy::Tables,
                UpdateKind::Factor,
                &cfg,
                &refresh_rust,
                &mut st_static,
            );
            run_epoch_with(
                &mut m_steal,
                &coo,
                ChainStrategy::Tables,
                UpdateKind::Factor,
                &cfg_steal,
                &refresh_rust,
                &mut st_steal,
            );
        }
        for n in 0..3 {
            assert_eq!(m_steal.factors[n].max_abs_diff(&m_static.factors[n]), 0.0);
            assert_eq!(m_steal.cores[n].max_abs_diff(&m_static.cores[n]), 0.0);
            assert_eq!(m_steal.c_tables[n].max_abs_diff(&m_static.c_tables[n]), 0.0);
        }
    }

    /// The canonical-merge-order invariant: a stealing core pass folds
    /// per-block slots in ascending block id, so its merged gradient bits
    /// cannot depend on worker count or steal schedule. Factors are
    /// read-only during a core pass, so whole core epochs must be
    /// bit-identical at every worker count.
    #[test]
    fn stealing_core_epochs_bitwise_identical_across_worker_counts() {
        let (m0, t, base) = setup();
        let coo = CooBlocks::new(&t, base.block_nnz);
        let reference = {
            let mut m = m0.clone();
            let cfg = TrainConfig {
                workers: 1,
                sched: crate::config::SchedMode::Stealing,
                ..base.clone()
            };
            let mut st = EngineState::new();
            for _ in 0..2 {
                run_epoch_with(
                    &mut m,
                    &coo,
                    ChainStrategy::Tables,
                    UpdateKind::Core,
                    &cfg,
                    &refresh_rust,
                    &mut st,
                );
            }
            m
        };
        for workers in [2usize, 3, 8] {
            let mut m = m0.clone();
            let cfg = TrainConfig {
                workers,
                sched: crate::config::SchedMode::Stealing,
                ..base.clone()
            };
            let mut st = EngineState::new();
            for _ in 0..2 {
                run_epoch_with(
                    &mut m,
                    &coo,
                    ChainStrategy::Tables,
                    UpdateKind::Core,
                    &cfg,
                    &refresh_rust,
                    &mut st,
                );
            }
            for n in 0..3 {
                assert_eq!(
                    m.cores[n].max_abs_diff(&reference.cores[n]),
                    0.0,
                    "{workers} workers, mode {n}"
                );
                assert_eq!(m.c_tables[n].max_abs_diff(&reference.c_tables[n]), 0.0);
            }
        }
    }

    /// A rebuilt storage must drop the cached plans even when the block
    /// count happens to match: `set_storage_epoch` with a new generation
    /// clears them; the same generation is a no-op.
    #[test]
    fn storage_epoch_change_drops_cached_plans() {
        let (mut model, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let mut st = EngineState::new();
        st.set_storage_epoch(1);
        run_epoch_with(
            &mut model,
            &coo,
            ChainStrategy::Tables,
            UpdateKind::Factor,
            &cfg,
            &refresh_rust,
            &mut st,
        );
        assert_eq!(st.plan_block_counts().len(), 3, "plans cached per mode");
        st.set_storage_epoch(1);
        assert_eq!(st.plan_block_counts().len(), 3, "same epoch keeps plans");
        st.set_storage_epoch(2);
        assert!(st.plan_block_counts().is_empty(), "new epoch drops plans");
        assert_eq!(st.storage_epoch(), 2);
    }

    /// Tiling chunks the existing traversal order and prefetch is a pure
    /// hint, so any tile size must reproduce the untiled bits exactly —
    /// here a pathological 3-nnz tile against the auto cost model.
    #[test]
    fn tiled_epochs_are_bitwise_untiled_epochs() {
        let (m0, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let cfg_tiny = TrainConfig { tile_nnz: 3, ..cfg.clone() };
        let mut m_auto = m0.clone();
        let mut m_tiny = m0;
        let mut st_a = EngineState::new();
        let mut st_t = EngineState::new();
        for _ in 0..2 {
            for kind in [UpdateKind::Factor, UpdateKind::Core] {
                run_epoch_with(
                    &mut m_auto,
                    &coo,
                    ChainStrategy::Tables,
                    kind,
                    &cfg,
                    &refresh_rust,
                    &mut st_a,
                );
                run_epoch_with(
                    &mut m_tiny,
                    &coo,
                    ChainStrategy::Tables,
                    kind,
                    &cfg_tiny,
                    &refresh_rust,
                    &mut st_t,
                );
            }
        }
        for n in 0..3 {
            assert_eq!(m_tiny.factors[n].max_abs_diff(&m_auto.factors[n]), 0.0);
            assert_eq!(m_tiny.cores[n].max_abs_diff(&m_auto.cores[n]), 0.0);
            assert_eq!(m_tiny.c_tables[n].max_abs_diff(&m_auto.c_tables[n]), 0.0);
        }
    }

    /// Node replicas are byte copies and per-node scratch pools only move
    /// *where* buffers live, so a synthetic 2-node homed run must equal
    /// the unhomed single-node bits exactly. Core passes under stealing
    /// are deterministic at any worker count (canonical slot fold), which
    /// makes them the right probe for workers > 1.
    #[test]
    fn homed_replicated_core_epochs_match_unhomed_bitwise() {
        let (m0, t, base) = setup();
        let coo = CooBlocks::new(&t, base.block_nnz);
        let reference = {
            let mut m = m0.clone();
            let cfg = TrainConfig {
                workers: 1,
                sched: crate::config::SchedMode::Stealing,
                ..base.clone()
            };
            let mut st = EngineState::new();
            for _ in 0..2 {
                run_epoch_with(
                    &mut m,
                    &coo,
                    ChainStrategy::Tables,
                    UpdateKind::Core,
                    &cfg,
                    &refresh_rust,
                    &mut st,
                );
            }
            m
        };
        for workers in [2usize, 3] {
            let cfg = TrainConfig {
                workers,
                sched: crate::config::SchedMode::Stealing,
                tile_nnz: 5,
                ..base.clone()
            };
            let mut m = m0.clone();
            let mut st = EngineState::new();
            let topo2 = crate::sched::topo::Topology::synthetic(2);
            st.set_worker_homes(topo2.assign_homes(workers));
            assert_eq!(st.worker_homes().len(), workers);
            for _ in 0..2 {
                run_epoch_with(
                    &mut m,
                    &coo,
                    ChainStrategy::Tables,
                    UpdateKind::Core,
                    &cfg,
                    &refresh_rust,
                    &mut st,
                );
            }
            for n in 0..3 {
                assert_eq!(
                    m.cores[n].max_abs_diff(&reference.cores[n]),
                    0.0,
                    "{workers} workers, mode {n}"
                );
                assert_eq!(m.c_tables[n].max_abs_diff(&reference.c_tables[n]), 0.0);
            }
            // the migration counter drains without touching the math
            let _ = st.take_cross_node_steals();
            assert_eq!(st.take_cross_node_steals(), 0, "drained");
        }
    }

    /// The replica-coherence invariant the homed readers rely on: after
    /// every pass — including ones whose refresh was the dirty-row
    /// incremental path — every mirror is byte-identical to the primary.
    #[test]
    fn mirror_tables_stay_bitwise_coherent_across_incremental_refreshes() {
        let (mut m, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let mut st = EngineState::new();
        let topo3 = crate::sched::topo::Topology::synthetic(3);
        st.set_worker_homes(topo3.assign_homes(4));
        let inc = |mm: &mut ModelState, n: usize| mm.refresh_c_dirty(n, None);
        for _ in 0..2 {
            for kind in [UpdateKind::Factor, UpdateKind::Core] {
                run_epoch_with(
                    &mut m,
                    &coo,
                    ChainStrategy::Tables,
                    kind,
                    &cfg,
                    &inc,
                    &mut st,
                );
            }
            for node in 1..3 {
                for n in 0..3 {
                    assert_eq!(
                        st.padded_c.get(node)[n]
                            .max_abs_diff(&st.padded_c.get(0)[n]),
                        0.0,
                        "node {node} mode {n}"
                    );
                }
                assert_eq!(
                    st.padded_core.get(node).max_abs_diff(st.padded_core.get(0)),
                    0.0
                );
            }
        }
    }

    /// Pooled scratches and cached padded operands must be invisible to the
    /// math: epochs driven through one persistent `EngineState` equal the
    /// same epochs with a fresh state each time, bit for bit.
    #[test]
    fn persistent_engine_state_matches_fresh_state() {
        let (m0, t, cfg) = setup();
        let coo = CooBlocks::new(&t, cfg.block_nnz);
        let mut m_persist = m0.clone();
        let mut m_fresh = m0;
        let mut state = EngineState::new();
        for _ in 0..2 {
            for kind in [UpdateKind::Factor, UpdateKind::Core] {
                run_epoch_with(
                    &mut m_persist,
                    &coo,
                    ChainStrategy::Tables,
                    kind,
                    &cfg,
                    &refresh_rust,
                    &mut state,
                );
                run_epoch(&mut m_fresh, &coo, ChainStrategy::Tables, kind, &cfg, &refresh_rust);
            }
        }
        for n in 0..3 {
            assert_eq!(m_persist.factors[n].max_abs_diff(&m_fresh.factors[n]), 0.0);
            assert_eq!(m_persist.cores[n].max_abs_diff(&m_fresh.cores[n]), 0.0);
            assert_eq!(m_persist.c_tables[n].max_abs_diff(&m_fresh.c_tables[n]), 0.0);
        }
    }
}
