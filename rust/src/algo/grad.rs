//! Shared gradient math for the FastTucker family (paper eq. 9–12).
//!
//! The canonical implementations live in [`super::kernels`] — the
//! R-blocked, rank-padding-aware kernel layer introduced with the batched
//! engine. This module re-exports them under the historical `algo::grad`
//! paths so the frozen reference loops in `tests/engine_parity.rs`, the
//! property tests, and the benches keep reading the exact same primitives
//! the engine executes (that shared-primitive discipline is what makes the
//! parity suite's `max_abs_diff == 0.0` assertion meaningful).

pub use super::kernels::{
    accumulate_core_grad, apply_core_grad, chain_v_from_tables,
    chain_v_on_the_fly, chain_v_prefix_cached, fiber_w, Scratch,
};
