//! Shared gradient math for the FastTucker family (paper eq. 9–12).
//!
//! For a non-zero `x` at coordinates `(i_1..i_N)` and update mode `n`:
//!
//! * `v_r = s^(n) q^(n)_{:,r} = Π_{n'≠n} (a_{i_{n'}}^(n') · b_{:,r}^(n'))`
//!   — the chain of scalar products (eq. 12). FasterTucker reads each
//!   factor from the precomputed `C` tables; FastTucker recomputes the dots.
//! * `w = B^(n) v ∈ R^J` — the paper's shared invariant
//!   `B^(n) Q^(n)ᵀ s^(n)ᵀ`, identical for every non-zero of a mode-n fiber.
//! * `x̂ = a_{i_n} · w`, error `e = x − x̂`.
//! * factor step (eq. 10): `a ← a + γ_A (e·w − λ_A·a)`.
//! * core step (eq. 11):  `grad b_{:,r} += e·v_r·a_{i_n}`, applied once per
//!   epoch as `B ← B + γ_B (G/|Ω| − λ_B·B)`.

use crate::linalg::Matrix;

/// Per-worker scratch buffers: everything the inner loops need, allocated
/// once per worker per epoch (paper: registers + shared memory; here: one
/// heap allocation outside the hot loop).
pub struct Scratch {
    /// `v ∈ R^R` — the chain products.
    pub v: Vec<f32>,
    /// `w ∈ R^J` — the fiber-shared intermediate.
    pub w: Vec<f32>,
    /// row buffer `∈ R^J`.
    pub row: Vec<f32>,
    /// previous fiber path (for prefix-product caching).
    pub prev_path: Vec<u32>,
    /// coordinate sub-tuple buffer (COO paths: the N−1 non-update coords).
    pub sub: Vec<u32>,
    /// partial prefix products per internal level: `(N-1) × R` row-major.
    pub pprod: Vec<f32>,
    /// core-gradient accumulator `J×R` (core epochs only).
    pub grad: Matrix,
}

impl Scratch {
    pub fn new(order: usize, j: usize, r: usize) -> Scratch {
        Scratch {
            v: vec![0.0; r],
            w: vec![0.0; j],
            row: vec![0.0; j],
            prev_path: Vec::new(),
            sub: Vec::with_capacity(order),
            pprod: vec![0.0; (order.max(2) - 1) * r],
            grad: Matrix::zeros(j, r),
        }
    }

    /// Invalidate the prefix cache (call when starting a new block, whose
    /// first fiber has no guaranteed relation to the previous one).
    pub fn reset_prefix(&mut self) {
        self.prev_path.clear();
    }
}

/// `v_r = Π_k C[modes[k]][coords[k], r]` — FasterTucker's table lookup form.
#[inline]
pub fn chain_v_from_tables(
    c_tables: &[Matrix],
    modes: &[usize],
    coords: &[u32],
    v: &mut [f32],
) {
    debug_assert_eq!(modes.len(), coords.len());
    v.fill(1.0);
    for (&m, &c) in modes.iter().zip(coords.iter()) {
        let crow = c_tables[m].row(c as usize);
        for (vr, &cr) in v.iter_mut().zip(crow.iter()) {
            *vr *= cr;
        }
    }
}

/// Prefix-cached variant: reuses partial products for the leading path
/// levels shared with the previous fiber (the CSF-tree walk of Algorithm 4:
/// upper-level `a·b` rows are only re-read when the tree branch changes).
///
/// `modes[k]`/`path[k]` are the internal levels in CSF order; `pprod` holds
/// the running product after each level.
#[inline]
pub fn chain_v_prefix_cached(
    c_tables: &[Matrix],
    modes: &[usize],
    path: &[u32],
    scratch: &mut Scratch,
) {
    let r = scratch.v.len();
    let plen = modes.len();
    debug_assert_eq!(path.len(), plen);
    // longest shared prefix with previous fiber
    let shared = if scratch.prev_path.len() == plen {
        scratch
            .prev_path
            .iter()
            .zip(path.iter())
            .take_while(|(a, b)| a == b)
            .count()
    } else {
        0
    };
    for k in shared..plen {
        let crow = c_tables[modes[k]].row(path[k] as usize);
        let (lo, hi) = (k * r, (k + 1) * r);
        if k == 0 {
            scratch.pprod[lo..hi].copy_from_slice(&crow[..r]);
        } else {
            // pprod[k] = pprod[k-1] * crow
            let (prev, cur) = scratch.pprod.split_at_mut(lo);
            let prev = &prev[lo - r..];
            for i in 0..r {
                cur[i] = prev[i] * crow[i];
            }
        }
    }
    scratch.v.copy_from_slice(&scratch.pprod[(plen - 1) * r..plen * r]);
    scratch.prev_path.clear();
    scratch.prev_path.extend_from_slice(path);
}

/// `v_r = Π_k (A[modes[k]][coords[k]] · B[modes[k]][:,r])` — FastTucker's
/// on-the-fly form: `(N−1)·J·R` multiplications per non-zero (the cost the
/// paper's Theory contribution removes).
#[inline]
pub fn chain_v_on_the_fly(
    factors: &[Matrix],
    cores: &[Matrix],
    modes: &[usize],
    coords: &[u32],
    v: &mut [f32],
) {
    v.fill(1.0);
    for (&m, &c) in modes.iter().zip(coords.iter()) {
        let a = factors[m].row(c as usize);
        let b = &cores[m];
        let j = b.rows();
        for (rr, vr) in v.iter_mut().enumerate() {
            let mut d = 0.0f32;
            for jj in 0..j {
                d += a[jj] * b.get(jj, rr);
            }
            *vr *= d;
        }
    }
}

/// `w = B v` (J×R times R). The fiber-shared intermediate.
/// (§Perf log: a 4-way-unrolled dot variant measured *slower* here —
/// 476 vs 330 ns — the simple loop already auto-vectorizes; kept simple.)
#[inline]
pub fn fiber_w(b: &Matrix, v: &[f32], w: &mut [f32]) {
    debug_assert_eq!(b.cols(), v.len());
    debug_assert_eq!(b.rows(), w.len());
    let r = v.len();
    for (wj, brow) in w.iter_mut().zip(b.data().chunks_exact(r)) {
        let mut s = 0.0f32;
        for (&bv, &vv) in brow.iter().zip(v.iter()) {
            s += bv * vv;
        }
        *wj = s;
    }
}

/// Accumulate the core gradient for one non-zero:
/// `G[:,r] += e·v_r·a` for all r (eq. 11, sign folded so the caller applies
/// `B += γ(G/|Ω| − λB)`).
#[inline]
pub fn accumulate_core_grad(grad: &mut Matrix, e: f32, v: &[f32], a: &[f32]) {
    let r = grad.cols();
    debug_assert_eq!(v.len(), r);
    debug_assert_eq!(a.len(), grad.rows());
    // (§Perf log: a 2-rows-per-iteration variant measured ~2× slower —
    // the simple row-axpy form auto-vectorizes best; kept simple.)
    let gdata = grad.data_mut();
    for (grow, &aj) in gdata.chunks_exact_mut(r).zip(a.iter()) {
        let ea = e * aj;
        for (g, &vr) in grow.iter_mut().zip(v.iter()) {
            *g += ea * vr;
        }
    }
}

/// Apply the accumulated core gradient:
/// `B ← B + γ_B (G/|Ω| − λ_B B)`.
pub fn apply_core_grad(b: &mut Matrix, grad: &Matrix, nnz: usize, lr: f32, lambda: f32) {
    debug_assert_eq!(b.rows(), grad.rows());
    debug_assert_eq!(b.cols(), grad.cols());
    let inv = 1.0 / nnz.max(1) as f32;
    for (bv, gv) in b.data_mut().iter_mut().zip(grad.data().iter()) {
        *bv += lr * (gv * inv - lambda * *bv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy(seed: u64, order: usize, j: usize, r: usize, dim: usize) -> (Vec<Matrix>, Vec<Matrix>, Vec<Matrix>) {
        let mut rng = Rng::new(seed);
        let factors: Vec<Matrix> =
            (0..order).map(|_| Matrix::uniform(dim, j, -1.0, 1.0, &mut rng)).collect();
        let cores: Vec<Matrix> =
            (0..order).map(|_| Matrix::uniform(j, r, -1.0, 1.0, &mut rng)).collect();
        let c_tables: Vec<Matrix> =
            factors.iter().zip(cores.iter()).map(|(a, b)| a.matmul(b)).collect();
        (factors, cores, c_tables)
    }

    #[test]
    fn table_and_on_the_fly_chains_agree() {
        let (factors, cores, c_tables) = toy(1, 4, 6, 5, 10);
        let modes = [0usize, 2, 3];
        let coords = [3u32, 7, 1];
        let mut v1 = vec![0.0; 5];
        let mut v2 = vec![0.0; 5];
        chain_v_from_tables(&c_tables, &modes, &coords, &mut v1);
        chain_v_on_the_fly(&factors, &cores, &modes, &coords, &mut v2);
        for (a, b) in v1.iter().zip(v2.iter()) {
            assert!((a - b).abs() < 1e-4, "{v1:?} vs {v2:?}");
        }
    }

    #[test]
    fn prefix_cached_matches_uncached() {
        let (_, _, c_tables) = toy(2, 4, 6, 5, 10);
        let modes = [1usize, 2, 3];
        let mut scratch = Scratch::new(4, 6, 5);
        let paths: [[u32; 3]; 4] = [[2, 3, 4], [2, 3, 5], [2, 6, 0], [9, 0, 0]];
        for path in paths {
            chain_v_prefix_cached(&c_tables, &modes, &path, &mut scratch);
            let mut expect = vec![0.0; 5];
            chain_v_from_tables(&c_tables, &modes, &path, &mut expect);
            for (a, b) in scratch.v.iter().zip(expect.iter()) {
                assert!((a - b).abs() < 1e-5, "path {path:?}");
            }
        }
    }

    #[test]
    fn prefix_cache_reset_is_safe() {
        let (_, _, c_tables) = toy(3, 3, 4, 4, 8);
        let modes = [0usize, 1];
        let mut scratch = Scratch::new(3, 4, 4);
        chain_v_prefix_cached(&c_tables, &modes, &[1, 2], &mut scratch);
        scratch.reset_prefix();
        chain_v_prefix_cached(&c_tables, &modes, &[1, 3], &mut scratch);
        let mut expect = vec![0.0; 4];
        chain_v_from_tables(&c_tables, &modes, &[1, 3], &mut expect);
        for (a, b) in scratch.v.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fiber_w_is_matvec() {
        let b = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let v = [1.0f32, 0.5, 2.0];
        let mut w = [0.0f32; 2];
        fiber_w(&b, &v, &mut w);
        assert_eq!(w, [1.0 + 1.0 + 6.0, 4.0 + 2.5 + 12.0]);
    }

    /// The factor gradient must match a finite-difference of the loss
    /// `f(a) = (x − a·w)² + λ‖a‖²` — the definitive correctness check.
    #[test]
    fn factor_step_matches_finite_difference() {
        let j = 5;
        let mut rng = Rng::new(7);
        let a: Vec<f32> = (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let x = 1.7f32;
        let lambda = 0.3f32;
        let loss = |a: &[f32]| -> f64 {
            let xhat: f32 = a.iter().zip(w.iter()).map(|(ai, wi)| ai * wi).sum();
            let e = (x - xhat) as f64;
            e * e + lambda as f64 * a.iter().map(|&ai| (ai * ai) as f64).sum::<f64>()
        };
        // analytic gradient of the loss: −2e·w + 2λa; our step uses e·w − λa
        // (the ½-scaled negative gradient, standard for SGD implementations)
        let xhat: f32 = a.iter().zip(w.iter()).map(|(ai, wi)| ai * wi).sum();
        let e = x - xhat;
        for k in 0..j {
            let step_dir = e * w[k] - lambda * a[k];
            let h = 1e-3f32;
            let mut ap = a.clone();
            ap[k] += h;
            let mut am = a.clone();
            am[k] -= h;
            let fd = -((loss(&ap) - loss(&am)) / (2.0 * h as f64)) / 2.0;
            assert!(
                (fd - step_dir as f64).abs() < 1e-2,
                "k={k}: fd {fd} vs step {step_dir}"
            );
        }
    }

    /// Core gradient ↔ finite difference of `f(b_r) = (x − x̂)² + λ‖b_r‖²`
    /// where `x̂ = Σ_r (a·b_r)·v_r` and v depends on the *other* modes only.
    #[test]
    fn core_step_matches_finite_difference() {
        let (j, r) = (4, 3);
        let mut rng = Rng::new(8);
        let a: Vec<f32> = (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let v: Vec<f32> = (0..r).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let mut b = Matrix::uniform(j, r, -1.0, 1.0, &mut rng);
        let x = 0.9f32;
        let predict = |b: &Matrix| -> f32 {
            let mut acc = 0.0;
            for rr in 0..r {
                let mut d = 0.0;
                for jj in 0..j {
                    d += a[jj] * b.get(jj, rr);
                }
                acc += d * v[rr];
            }
            acc
        };
        let e = x - predict(&b);
        let mut grad = Matrix::zeros(j, r);
        accumulate_core_grad(&mut grad, e, &v, &a);
        // finite difference of ½(x−x̂)² wrt b[jj,rr] should equal −grad
        for jj in 0..j {
            for rr in 0..r {
                let h = 1e-3f32;
                let orig = b.get(jj, rr);
                b.set(jj, rr, orig + h);
                let lp = {
                    let e = (x - predict(&b)) as f64;
                    0.5 * e * e
                };
                b.set(jj, rr, orig - h);
                let lm = {
                    let e = (x - predict(&b)) as f64;
                    0.5 * e * e
                };
                b.set(jj, rr, orig);
                let fd = -(lp - lm) / (2.0 * h as f64);
                assert!(
                    (fd - grad.get(jj, rr) as f64).abs() < 5e-2,
                    "({jj},{rr}): fd {fd} vs {}",
                    grad.get(jj, rr)
                );
            }
        }
    }

    #[test]
    fn apply_core_grad_formula() {
        let mut b = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let g = Matrix::from_vec(1, 2, vec![10.0, 20.0]);
        apply_core_grad(&mut b, &g, 10, 0.1, 0.5);
        // b += 0.1*(g/10 − 0.5*b)
        assert!((b.get(0, 0) - (1.0 + 0.1 * (1.0 - 0.5))).abs() < 1e-6);
        assert!((b.get(0, 1) - (2.0 + 0.1 * (2.0 - 1.0))).abs() < 1e-6);
    }
}
