//! FasterTucker — the paper's contribution (§III, Algorithms 2–5).
//!
//! Two variants, matching the paper's ablation:
//!
//! * **COO variant** (`*_coo`, paper "cuFasterTucker_COO"): only the
//!   *reusable* intermediates — the chain scalars come from the precomputed
//!   tables `C^(n) = A^(n) B^(n)` instead of fresh dot products, cutting the
//!   dominant cost from `(N−1)|Ω| Σ J R` to `Σ I_n J R` per epoch. The
//!   fiber-shared intermediate `w` is still recomputed per non-zero.
//! * **B-CSF variant** (`*_bcsf`, paper "cuFasterTucker"): additionally
//!   groups non-zeros by mode-n fiber (B-CSF storage) so `v` and
//!   `w = B^(n) v` are computed once per (sub-)fiber and shared by all its
//!   non-zeros — the *shared invariant* intermediates of §III-B. Upper
//!   tree levels reuse prefix products exactly like Algorithm 4's cached
//!   `a·b` rows.
//!
//! After each mode's update the mode's C table is refreshed
//! (Algorithm 3) — `refresh` is injected so the coordinator can route it to
//! the in-crate GEMM or the AOT/PJRT kernel.

use crate::config::TrainConfig;
use crate::linalg::Matrix;
use crate::model::ModelState;
use crate::sched::pool::parallel_reduce;
use crate::sched::racy::RacyMatrix;
use crate::tensor::bcsf::BcsfTensor;
use crate::tensor::coo::CooTensor;
use crate::util::ceil_div;

use super::fastucker::other_modes;
use super::grad::{
    accumulate_core_grad, apply_core_grad, chain_v_from_tables, chain_v_prefix_cached,
    fiber_w, Scratch,
};

/// How the coordinator refreshes `C^(n)` after a mode update.
pub type RefreshC<'a> = dyn Fn(&mut ModelState, usize) + 'a;

/// Default refresh: in-crate GEMM.
pub fn refresh_rust(model: &mut ModelState, n: usize) {
    model.refresh_c(n);
}

// ---------------------------------------------------------------- COO variant

/// Factor epoch, COO variant (reusable intermediates only).
pub fn factor_epoch_coo(
    model: &mut ModelState,
    data: &CooTensor,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let order = model.order();
    let nnz = data.nnz();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let block = cfg.block_nnz.max(1);
    let num_blocks = ceil_div(nnz, block);
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;

    for n in 0..order {
        let modes = other_modes(order, n);
        let mut target = std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target);
            let c_tables = &model.c_tables;
            let core_n = &model.cores[n];
            parallel_reduce(
                workers,
                num_blocks,
                || Scratch::new(order, j, r),
                |s, _w, b| {
                    let lo = b * block;
                    let hi = (lo + block).min(nnz);
                    for e in lo..hi {
                        let coords = data.index(e);
                        let x = data.value(e);
                        s.sub.clear();
                        s.sub.extend(modes.iter().map(|&m| coords[m]));
                        let Scratch { sub, v, .. } = s;
                        chain_v_from_tables(c_tables, &modes, sub, v);
                        fiber_w(core_n, &s.v, &mut s.w);
                        let i = coords[n] as usize;
                        let e_val = x - racy.row_dot(i, &s.w);
                        racy.row_sgd_update(i, scale, cfg.lr_a * e_val, &s.w);
                    }
                },
                |_acc, _other| {},
            );
        }
        model.factors[n] = target;
        refresh(model, n);
    }
}

/// Core epoch, COO variant.
pub fn core_epoch_coo(
    model: &mut ModelState,
    data: &CooTensor,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let order = model.order();
    let nnz = data.nnz();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let block = cfg.block_nnz.max(1);
    let num_blocks = ceil_div(nnz, block);

    for n in 0..order {
        let modes = other_modes(order, n);
        let grad = {
            let c_tables = &model.c_tables;
            let factors = &model.factors;
            let core_n = &model.cores[n];
            parallel_reduce(
                workers,
                num_blocks,
                || Scratch::new(order, j, r),
                |s, _w, b| {
                    let lo = b * block;
                    let hi = (lo + block).min(nnz);
                    for e in lo..hi {
                        let coords = data.index(e);
                        let x = data.value(e);
                        s.sub.clear();
                        s.sub.extend(modes.iter().map(|&m| coords[m]));
                        let Scratch { sub, v, .. } = s;
                        chain_v_from_tables(c_tables, &modes, sub, v);
                        fiber_w(core_n, &s.v, &mut s.w);
                        let a = factors[n].row(coords[n] as usize);
                        let xhat = crate::linalg::dot(a, &s.w);
                        accumulate_core_grad(&mut s.grad, x - xhat, &s.v, a);
                    }
                },
                |acc, other| {
                    for (g, o) in
                        acc.grad.data_mut().iter_mut().zip(other.grad.data())
                    {
                        *g += o;
                    }
                },
            )
            .grad
        };
        apply_core_grad(&mut model.cores[n], &grad, nnz, cfg.lr_b, cfg.lambda_b);
        refresh(model, n);
    }
}

// -------------------------------------------------------------- B-CSF variant

/// Factor epoch, full cuFasterTucker: B-CSF blocks → sub-fibers → leaves.
/// `bcsf[n]` must be the rotation with leaf mode `n`.
pub fn factor_epoch_bcsf(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;

    for n in 0..order {
        let t = &bcsf[n];
        debug_assert_eq!(t.csf.leaf_mode(), n);
        let internal_modes = &t.csf.mode_order[..order - 1];
        let num_blocks = t.num_blocks();
        let mut target = std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target);
            let c_tables = &model.c_tables;
            let core_n = &model.cores[n];
            parallel_reduce(
                workers,
                num_blocks,
                || Scratch::new(order, j, r),
                |s, _w, blk| {
                    s.reset_prefix();
                    let mut prev_fiber = u32::MAX;
                    for task in t.block_tasks(blk) {
                        // v (chain products) and w (B·v) are shared by every
                        // leaf of the sub-fiber — computed once here.
                        if task.fiber != prev_fiber {
                            let path = t.fiber_path(task.fiber);
                            chain_v_prefix_cached(c_tables, internal_modes, path, s);
                            fiber_w(core_n, &s.v, &mut s.w);
                            prev_fiber = task.fiber;
                        }
                        let (leaf_idx, leaf_vals) = t.task_leaves(task);
                        for (k, &i) in leaf_idx.iter().enumerate() {
                            let i = i as usize;
                            let x = leaf_vals[k];
                            let e_val = x - racy.row_dot(i, &s.w);
                            racy.row_sgd_update(i, scale, cfg.lr_a * e_val, &s.w);
                        }
                    }
                },
                |_acc, _other| {},
            );
        }
        model.factors[n] = target;
        refresh(model, n);
    }
}

/// Factor epoch, "cuFasterTucker_B-CSF" ablation: identical traversal order
/// to the full variant (so it inherits B-CSF's locality), but `v` and `w`
/// are recomputed for *every* non-zero — isolating the benefit of the
/// shared invariant intermediates (paper Table V row 3 vs row 4).
pub fn factor_epoch_bcsf_noshare(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;

    for n in 0..order {
        let t = &bcsf[n];
        let internal_modes = &t.csf.mode_order[..order - 1];
        let num_blocks = t.num_blocks();
        let mut target = std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target);
            let c_tables = &model.c_tables;
            let core_n = &model.cores[n];
            parallel_reduce(
                workers,
                num_blocks,
                || Scratch::new(order, j, r),
                |s, _w, blk| {
                    for task in t.block_tasks(blk) {
                        let path = t.fiber_path(task.fiber);
                        let (leaf_idx, leaf_vals) = t.task_leaves(task);
                        for (k, &i) in leaf_idx.iter().enumerate() {
                            // per-element recomputation (the ablation)
                            chain_v_from_tables(c_tables, internal_modes, path, &mut s.v);
                            fiber_w(core_n, &s.v, &mut s.w);
                            let i = i as usize;
                            let e_val = leaf_vals[k] - racy.row_dot(i, &s.w);
                            racy.row_sgd_update(i, scale, cfg.lr_a * e_val, &s.w);
                        }
                    }
                },
                |_acc, _other| {},
            );
        }
        model.factors[n] = target;
        refresh(model, n);
    }
}

/// Core epoch for the "cuFasterTucker_B-CSF" ablation (per-element `v`/`w`).
pub fn core_epoch_bcsf_noshare(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();

    for n in 0..order {
        let t = &bcsf[n];
        let internal_modes = &t.csf.mode_order[..order - 1];
        let num_blocks = t.num_blocks();
        let nnz = t.nnz();
        let grad = {
            let c_tables = &model.c_tables;
            let factors = &model.factors;
            let core_n = &model.cores[n];
            parallel_reduce(
                workers,
                num_blocks,
                || Scratch::new(order, j, r),
                |s, _w, blk| {
                    for task in t.block_tasks(blk) {
                        let path = t.fiber_path(task.fiber);
                        let (leaf_idx, leaf_vals) = t.task_leaves(task);
                        for (k, &i) in leaf_idx.iter().enumerate() {
                            chain_v_from_tables(c_tables, internal_modes, path, &mut s.v);
                            fiber_w(core_n, &s.v, &mut s.w);
                            let a = factors[n].row(i as usize);
                            let xhat = crate::linalg::dot(a, &s.w);
                            accumulate_core_grad(
                                &mut s.grad,
                                leaf_vals[k] - xhat,
                                &s.v,
                                a,
                            );
                        }
                    }
                },
                |acc, other| {
                    for (g, o) in
                        acc.grad.data_mut().iter_mut().zip(other.grad.data())
                    {
                        *g += o;
                    }
                },
            )
            .grad
        };
        apply_core_grad(&mut model.cores[n], &grad, nnz, cfg.lr_b, cfg.lambda_b);
        refresh(model, n);
    }
}

/// Core epoch, full cuFasterTucker (Algorithm 5): fiber-shared `v`/`w`,
/// per-worker gradient accumulation, single batched update per mode.
pub fn core_epoch_bcsf(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let workers = cfg.effective_workers();

    for n in 0..order {
        let t = &bcsf[n];
        let internal_modes = &t.csf.mode_order[..order - 1];
        let num_blocks = t.num_blocks();
        let nnz = t.nnz();
        let grad = {
            let c_tables = &model.c_tables;
            let factors = &model.factors;
            let core_n = &model.cores[n];
            parallel_reduce(
                workers,
                num_blocks,
                || Scratch::new(order, j, r),
                |s, _w, blk| {
                    s.reset_prefix();
                    let mut prev_fiber = u32::MAX;
                    for task in t.block_tasks(blk) {
                        if task.fiber != prev_fiber {
                            let path = t.fiber_path(task.fiber);
                            chain_v_prefix_cached(c_tables, internal_modes, path, s);
                            fiber_w(core_n, &s.v, &mut s.w);
                            prev_fiber = task.fiber;
                        }
                        let (leaf_idx, leaf_vals) = t.task_leaves(task);
                        for (k, &i) in leaf_idx.iter().enumerate() {
                            let a = factors[n].row(i as usize);
                            let xhat = crate::linalg::dot(a, &s.w);
                            accumulate_core_grad(
                                &mut s.grad,
                                leaf_vals[k] - xhat,
                                &s.v,
                                a,
                            );
                        }
                    }
                },
                |acc, other| {
                    for (g, o) in
                        acc.grad.data_mut().iter_mut().zip(other.grad.data())
                    {
                        *g += o;
                    }
                },
            )
            .grad
        };
        apply_core_grad(&mut model.cores[n], &grad, nnz, cfg.lr_b, cfg.lambda_b);
        refresh(model, n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::fastucker;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::metrics::rmse_mae;
    use crate::tensor::csf::CsfTensor;

    fn setup(workers: usize) -> (ModelState, CooTensor, TrainConfig) {
        let t = recommender(&RecommenderSpec::tiny(), 21);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers,
            block_nnz: 512,
            fiber_threshold: 32,
            ..TrainConfig::default()
        };
        let model = ModelState::init(&cfg, 5);
        (model, t, cfg)
    }

    fn build_bcsf(t: &CooTensor, cfg: &TrainConfig) -> Vec<BcsfTensor> {
        (0..t.order())
            .map(|n| BcsfTensor::build(t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect()
    }

    /// Core equivalence theorem of the paper: FasterTucker computes the SAME
    /// update as FastTucker, only faster. With identical element order and
    /// serial execution, one COO FastTucker epoch and one COO FasterTucker
    /// epoch must produce (near-)identical factors.
    #[test]
    fn coo_variant_equals_fastucker_serial() {
        let (m0, t, cfg) = setup(1);
        let mut m1 = m0.clone();
        let mut m2 = m0.clone();
        fastucker::factor_epoch(&mut m1, &t, &cfg);
        factor_epoch_coo(&mut m2, &t, &cfg, &refresh_rust);
        for n in 0..3 {
            let d = m1.factors[n].max_abs_diff(&m2.factors[n]);
            assert!(d < 1e-4, "mode {n}: max diff {d}");
        }
    }

    /// The B-CSF variant visits elements in fiber order; running FastTucker
    /// over a COO tensor *sorted in the same fiber order* must match.
    #[test]
    fn bcsf_variant_equals_fastucker_in_fiber_order() {
        let (m0, t, cfg) = setup(1);
        // same-order COO for each mode is impossible with a single COO pass
        // (each mode re-sorts), so compare one single-mode update instead:
        // restrict to mode 0 by zeroing lr after mode 0 — simpler: compare
        // full epochs with per-mode sorted COO replicas.
        let mut m_bcsf = m0.clone();
        let bcsf = build_bcsf(&t, &cfg);
        factor_epoch_bcsf(&mut m_bcsf, &bcsf, &cfg, &refresh_rust);

        let mut m_ref = m0.clone();
        for n in 0..3 {
            // emulate: FastTucker single-mode pass in fiber order
            let sorted = CsfTensor::build(&t, n).to_coo();
            let modes = other_modes(3, n);
            let mut s = Scratch::new(3, 8, 4);
            let scale = 1.0 - cfg.lr_a * cfg.lambda_a;
            for e in 0..sorted.nnz() {
                let coords = sorted.index(e);
                let x = sorted.value(e);
                s.sub.clear();
                s.sub.extend(modes.iter().map(|&m| coords[m]));
                chain_v_from_tables(&m_ref.c_tables, &modes, &s.sub, &mut s.v);
                fiber_w(&m_ref.cores[n], &s.v, &mut s.w);
                let i = coords[n] as usize;
                let a = m_ref.factors[n].row(i);
                let mut xhat = 0.0;
                for (aj, wj) in a.iter().zip(s.w.iter()) {
                    xhat += aj * wj;
                }
                let e_val = x - xhat;
                let row = m_ref.factors[n].row_mut(i);
                for (rj, wj) in row.iter_mut().zip(s.w.iter()) {
                    *rj = scale * *rj + cfg.lr_a * e_val * wj;
                }
            }
            m_ref.refresh_c(n);
        }
        for n in 0..3 {
            let d = m_bcsf.factors[n].max_abs_diff(&m_ref.factors[n]);
            assert!(d < 1e-4, "mode {n}: max diff {d}");
        }
    }

    #[test]
    fn core_epochs_agree_across_variants() {
        let (m0, t, cfg) = setup(1);
        let mut m1 = m0.clone();
        let mut m2 = m0.clone();
        let mut m3 = m0.clone();
        fastucker::core_epoch(&mut m1, &t, &cfg);
        core_epoch_coo(&mut m2, &t, &cfg, &refresh_rust);
        let bcsf = build_bcsf(&t, &cfg);
        core_epoch_bcsf(&mut m3, &bcsf, &cfg, &refresh_rust);
        for n in 0..3 {
            let d12 = m1.cores[n].max_abs_diff(&m2.cores[n]);
            let d13 = m1.cores[n].max_abs_diff(&m3.cores[n]);
            assert!(d12 < 1e-4, "core {n} coo diff {d12}");
            assert!(d13 < 1e-4, "core {n} bcsf diff {d13}");
        }
    }

    #[test]
    fn bcsf_training_converges_parallel() {
        let (mut model, t, cfg) = setup(4);
        let bcsf = build_bcsf(&t, &cfg);
        let (before, _) = rmse_mae(&model, &t, 2);
        for _ in 0..5 {
            factor_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
            core_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
        }
        let (after, _) = rmse_mae(&model, &t, 2);
        assert!(after < before * 0.9, "RMSE {before} -> {after}");
    }

    #[test]
    fn coo_training_converges() {
        let (mut model, t, cfg) = setup(2);
        let (before, _) = rmse_mae(&model, &t, 2);
        for _ in 0..5 {
            factor_epoch_coo(&mut model, &t, &cfg, &refresh_rust);
            core_epoch_coo(&mut model, &t, &cfg, &refresh_rust);
        }
        let (after, _) = rmse_mae(&model, &t, 2);
        assert!(after < before * 0.9, "RMSE {before} -> {after}");
    }

    #[test]
    fn c_tables_stay_synced() {
        let (mut model, t, cfg) = setup(1);
        let bcsf = build_bcsf(&t, &cfg);
        factor_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
        core_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
        for n in 0..3 {
            let expect = model.factors[n].matmul(&model.cores[n]);
            let d = expect.max_abs_diff(&model.c_tables[n]);
            assert!(d < 1e-5, "mode {n}: C table out of sync by {d}");
        }
    }

    #[test]
    fn high_order_tensor_works() {
        let t = crate::data::synthetic::order_sweep(5, 12, 800, 9);
        let cfg = TrainConfig {
            order: 5,
            dims: t.dims().to_vec(),
            j: 4,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 2,
            fiber_threshold: 16,
            block_nnz: 128,
            ..TrainConfig::default()
        };
        let mut model = ModelState::init(&cfg, 1);
        let bcsf: Vec<BcsfTensor> = (0..5)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let (before, _) = rmse_mae(&model, &t, 1);
        for _ in 0..4 {
            factor_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
        }
        let (after, _) = rmse_mae(&model, &t, 1);
        assert!(after < before, "order-5 RMSE {before} -> {after}");
    }
}
