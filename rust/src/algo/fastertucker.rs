//! FasterTucker — the paper's contribution (§III, Algorithms 2–5), as
//! instantiations of the generic [`super::engine`].
//!
//! Two variants, matching the paper's ablation:
//!
//! * **COO variant** (`*_coo`, paper "cuFasterTucker_COO"): only the
//!   *reusable* intermediates — the chain scalars come from the precomputed
//!   tables `C^(n) = A^(n) B^(n)` ([`ChainStrategy::Tables`]) instead of
//!   fresh dot products, cutting the dominant cost from `(N−1)|Ω| Σ J R` to
//!   `Σ I_n J R` per epoch. The fiber-shared intermediate `w` is still
//!   recomputed per non-zero ([`CooBlocks`] groups are single elements).
//! * **B-CSF variant** (`*_bcsf`, paper "cuFasterTucker"): additionally
//!   groups non-zeros by mode-n fiber ([`BcsfShared`]) so `v` and
//!   `w = B^(n) v` are computed once per (sub-)fiber and shared by all its
//!   non-zeros — the *shared invariant* intermediates of §III-B. Upper tree
//!   levels reuse prefix products exactly like Algorithm 4's cached `a·b`
//!   rows ([`ChainStrategy::TablesPrefixCached`]).
//! * The `*_bcsf_noshare` ablation keeps B-CSF traversal order but
//!   recomputes `v`/`w` per non-zero ([`BcsfPerElement`] +
//!   [`ChainStrategy::Tables`]), paper Table V row 3 vs row 4.
//!
//! After each mode's update the mode's C table is refreshed (Algorithm 3) —
//! `refresh` is injected so the coordinator can route it to the in-crate
//! GEMM or the AOT/PJRT kernel.
//!
//! The legacy hand-written hot loops are gone; `tests/engine_parity.rs`
//! pins each instantiation to a frozen reference of the original loops with
//! exact f32 equality on one worker.

use crate::config::TrainConfig;
use crate::model::ModelState;
use crate::tensor::bcsf::{BcsfPerElement, BcsfShared, BcsfTensor};
use crate::tensor::coo::{CooBlocks, CooTensor};

use super::engine::{self, ChainStrategy};

pub use super::engine::{refresh_rust, RefreshC};

// ---------------------------------------------------------------- COO variant

/// Factor epoch, COO variant (reusable intermediates only).
pub fn factor_epoch_coo(
    model: &mut ModelState,
    data: &CooTensor,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let storage = CooBlocks::new(data, cfg.block_nnz);
    engine::factor_epoch(model, &storage, ChainStrategy::Tables, cfg, refresh);
}

/// Core epoch, COO variant.
pub fn core_epoch_coo(
    model: &mut ModelState,
    data: &CooTensor,
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let storage = CooBlocks::new(data, cfg.block_nnz);
    engine::core_epoch(model, &storage, ChainStrategy::Tables, cfg, refresh);
}

// -------------------------------------------------------------- B-CSF variant

/// Factor epoch, full cuFasterTucker: B-CSF blocks → sub-fibers → leaves,
/// with fiber-shared `v`/`w` and prefix-cached chain products.
/// `bcsf[n]` must be the rotation with leaf mode `n`.
pub fn factor_epoch_bcsf(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let storage = BcsfShared::new(bcsf);
    engine::factor_epoch(model, &storage, ChainStrategy::TablesPrefixCached, cfg, refresh);
}

/// Factor epoch, "cuFasterTucker_B-CSF" ablation: identical traversal order
/// to the full variant (so it inherits B-CSF's locality), but `v` and `w`
/// are recomputed for *every* non-zero — isolating the benefit of the
/// shared invariant intermediates (paper Table V row 3 vs row 4).
pub fn factor_epoch_bcsf_noshare(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let storage = BcsfPerElement::new(bcsf);
    engine::factor_epoch(model, &storage, ChainStrategy::Tables, cfg, refresh);
}

/// Core epoch, full cuFasterTucker (Algorithm 5): fiber-shared `v`/`w`,
/// per-worker gradient accumulation, single batched update per mode.
pub fn core_epoch_bcsf(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let storage = BcsfShared::new(bcsf);
    engine::core_epoch(model, &storage, ChainStrategy::TablesPrefixCached, cfg, refresh);
}

/// Core epoch for the "cuFasterTucker_B-CSF" ablation (per-element `v`/`w`).
pub fn core_epoch_bcsf_noshare(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    refresh: &RefreshC,
) {
    let storage = BcsfPerElement::new(bcsf);
    engine::core_epoch(model, &storage, ChainStrategy::Tables, cfg, refresh);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::fastucker::{self, other_modes};
    use crate::algo::grad::{chain_v_from_tables, fiber_w, Scratch};
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::metrics::rmse_mae;
    use crate::tensor::csf::CsfTensor;

    fn setup(workers: usize) -> (ModelState, CooTensor, TrainConfig) {
        let t = recommender(&RecommenderSpec::tiny(), 21);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers,
            block_nnz: 512,
            fiber_threshold: 32,
            ..TrainConfig::default()
        };
        let model = ModelState::init(&cfg, 5);
        (model, t, cfg)
    }

    fn build_bcsf(t: &CooTensor, cfg: &TrainConfig) -> Vec<BcsfTensor> {
        (0..t.order())
            .map(|n| BcsfTensor::build(t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect()
    }

    /// Core equivalence theorem of the paper: FasterTucker computes the SAME
    /// update as FastTucker, only faster. With identical element order and
    /// serial execution, one COO FastTucker epoch and one COO FasterTucker
    /// epoch must produce (near-)identical factors.
    #[test]
    fn coo_variant_equals_fastucker_serial() {
        let (m0, t, cfg) = setup(1);
        let mut m1 = m0.clone();
        let mut m2 = m0.clone();
        fastucker::factor_epoch(&mut m1, &t, &cfg);
        factor_epoch_coo(&mut m2, &t, &cfg, &refresh_rust);
        for n in 0..3 {
            let d = m1.factors[n].max_abs_diff(&m2.factors[n]);
            assert!(d < 1e-4, "mode {n}: max diff {d}");
        }
    }

    /// The B-CSF variant visits elements in fiber order; running FastTucker
    /// over a COO tensor *sorted in the same fiber order* must match.
    #[test]
    fn bcsf_variant_equals_fastucker_in_fiber_order() {
        let (m0, t, cfg) = setup(1);
        // same-order COO for each mode is impossible with a single COO pass
        // (each mode re-sorts), so compare one single-mode update instead:
        // restrict to mode 0 by zeroing lr after mode 0 — simpler: compare
        // full epochs with per-mode sorted COO replicas.
        let mut m_bcsf = m0.clone();
        let bcsf = build_bcsf(&t, &cfg);
        factor_epoch_bcsf(&mut m_bcsf, &bcsf, &cfg, &refresh_rust);

        let mut m_ref = m0.clone();
        for n in 0..3 {
            // emulate: FastTucker single-mode pass in fiber order
            let sorted = CsfTensor::build(&t, n).to_coo();
            let modes = other_modes(3, n);
            let mut s = Scratch::new(3, 8, 4);
            let scale = 1.0 - cfg.lr_a * cfg.lambda_a;
            for e in 0..sorted.nnz() {
                let coords = sorted.index(e);
                let x = sorted.value(e);
                s.sub.clear();
                s.sub.extend(modes.iter().map(|&m| coords[m]));
                chain_v_from_tables(&m_ref.c_tables, &modes, &s.sub, &mut s.v);
                fiber_w(&m_ref.cores[n], &s.v, &mut s.w);
                let i = coords[n] as usize;
                let a = m_ref.factors[n].row(i);
                let mut xhat = 0.0;
                for (aj, wj) in a.iter().zip(s.w.iter()) {
                    xhat += aj * wj;
                }
                let e_val = x - xhat;
                let row = m_ref.factors[n].row_mut(i);
                for (rj, wj) in row.iter_mut().zip(s.w.iter()) {
                    *rj = scale * *rj + cfg.lr_a * e_val * wj;
                }
            }
            m_ref.refresh_c(n);
        }
        for n in 0..3 {
            let d = m_bcsf.factors[n].max_abs_diff(&m_ref.factors[n]);
            assert!(d < 1e-4, "mode {n}: max diff {d}");
        }
    }

    #[test]
    fn core_epochs_agree_across_variants() {
        let (m0, t, cfg) = setup(1);
        let mut m1 = m0.clone();
        let mut m2 = m0.clone();
        let mut m3 = m0.clone();
        fastucker::core_epoch(&mut m1, &t, &cfg);
        core_epoch_coo(&mut m2, &t, &cfg, &refresh_rust);
        let bcsf = build_bcsf(&t, &cfg);
        core_epoch_bcsf(&mut m3, &bcsf, &cfg, &refresh_rust);
        for n in 0..3 {
            let d12 = m1.cores[n].max_abs_diff(&m2.cores[n]);
            let d13 = m1.cores[n].max_abs_diff(&m3.cores[n]);
            assert!(d12 < 1e-4, "core {n} coo diff {d12}");
            assert!(d13 < 1e-4, "core {n} bcsf diff {d13}");
        }
    }

    #[test]
    fn bcsf_training_converges_parallel() {
        let (mut model, t, cfg) = setup(4);
        let bcsf = build_bcsf(&t, &cfg);
        let (before, _) = rmse_mae(&model, &t, 2);
        for _ in 0..5 {
            factor_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
            core_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
        }
        let (after, _) = rmse_mae(&model, &t, 2);
        assert!(after < before * 0.9, "RMSE {before} -> {after}");
    }

    #[test]
    fn coo_training_converges() {
        let (mut model, t, cfg) = setup(2);
        let (before, _) = rmse_mae(&model, &t, 2);
        for _ in 0..5 {
            factor_epoch_coo(&mut model, &t, &cfg, &refresh_rust);
            core_epoch_coo(&mut model, &t, &cfg, &refresh_rust);
        }
        let (after, _) = rmse_mae(&model, &t, 2);
        assert!(after < before * 0.9, "RMSE {before} -> {after}");
    }

    #[test]
    fn noshare_ablation_matches_shared_results_serial() {
        // Identical traversal order and update math — only the intermediate
        // recomputation strategy differs, so serial results must coincide.
        let (m0, t, cfg) = setup(1);
        let bcsf = build_bcsf(&t, &cfg);
        let mut m_shared = m0.clone();
        let mut m_noshare = m0.clone();
        factor_epoch_bcsf(&mut m_shared, &bcsf, &cfg, &refresh_rust);
        factor_epoch_bcsf_noshare(&mut m_noshare, &bcsf, &cfg, &refresh_rust);
        for n in 0..3 {
            let d = m_shared.factors[n].max_abs_diff(&m_noshare.factors[n]);
            assert!(d < 1e-5, "mode {n}: shared vs noshare diff {d}");
        }
        core_epoch_bcsf(&mut m_shared, &bcsf, &cfg, &refresh_rust);
        core_epoch_bcsf_noshare(&mut m_noshare, &bcsf, &cfg, &refresh_rust);
        for n in 0..3 {
            let d = m_shared.cores[n].max_abs_diff(&m_noshare.cores[n]);
            assert!(d < 1e-5, "core {n}: shared vs noshare diff {d}");
        }
    }

    #[test]
    fn c_tables_stay_synced() {
        let (mut model, t, cfg) = setup(1);
        let bcsf = build_bcsf(&t, &cfg);
        factor_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
        core_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
        for n in 0..3 {
            let expect = model.factors[n].matmul(&model.cores[n]);
            let d = expect.max_abs_diff(&model.c_tables[n]);
            assert!(d < 1e-5, "mode {n}: C table out of sync by {d}");
        }
    }

    #[test]
    fn high_order_tensor_works() {
        let t = crate::data::synthetic::order_sweep(5, 12, 800, 9);
        let cfg = TrainConfig {
            order: 5,
            dims: t.dims().to_vec(),
            j: 4,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 2,
            fiber_threshold: 16,
            block_nnz: 128,
            ..TrainConfig::default()
        };
        let mut model = ModelState::init(&cfg, 1);
        let bcsf: Vec<BcsfTensor> = (0..5)
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let (before, _) = rmse_mae(&model, &t, 1);
        for _ in 0..4 {
            factor_epoch_bcsf(&mut model, &bcsf, &cfg, &refresh_rust);
        }
        let (after, _) = rmse_mae(&model, &t, 1);
        assert!(after < before, "order-5 RMSE {before} -> {after}");
    }
}
