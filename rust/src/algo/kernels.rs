//! R-blocked hot-path kernels for the FastTucker family (paper eq. 9–12).
//!
//! For a non-zero `x` at coordinates `(i_1..i_N)` and update mode `n`:
//!
//! * `v_r = s^(n) q^(n)_{:,r} = Π_{n'≠n} (a_{i_{n'}}^(n') · b_{:,r}^(n'))`
//!   — the chain of scalar products (eq. 12). FasterTucker reads each
//!   factor from the precomputed `C` tables; FastTucker recomputes the dots.
//! * `w = B^(n) v ∈ R^J` — the paper's shared invariant
//!   `B^(n) Q^(n)ᵀ s^(n)ᵀ`, identical for every non-zero of a mode-n fiber.
//! * `x̂ = a_{i_n} · w`, error `e = x − x̂`.
//! * factor step (eq. 10): `a ← a + γ_A (e·w − λ_A·a)`.
//! * core step (eq. 11):  `grad b_{:,r} += e·v_r·a_{i_n}`, applied once per
//!   epoch as `B ← B + γ_B (G/|Ω| − λ_B·B)`.
//!
//! Every rank-direction loop is blocked into [`LANES`]-wide groups over the
//! rank-padded scratch buffers (`Scratch::new` sizes `v`/`pprod` to
//! [`pad_r`]`(R)`); reductions go through the single fixed tree in
//! [`crate::linalg::simd`]. Because zero padding is value-neutral and the
//! reduction tree is fixed, a kernel fed a rank-padded matrix produces
//! *bitwise* the same result as the same kernel fed the unpadded original —
//! which is what lets the engine run on padded `C`/core copies while
//! `tests/engine_parity.rs` replays the frozen loops on the raw model
//! matrices and still demands `max_abs_diff == 0.0`.
//!
//! §Perf log (see `benches/microbench.rs`, which emits `BENCH_epoch.json`
//! with the measured baseline-vs-current split for every run):
//! * pre-PR the kernels were scalar loops with a 4-way unrolled `row_dot`;
//!   a 4-way-unrolled `fiber_w` had measured *slower* (476 vs 330 ns)
//!   because the remainder handling defeated the auto-vectorizer.
//! * the 8-lane forms below remove the per-row remainder entirely on the
//!   padded fast path (`chunks_exact(LANES)`, no tail), which is the shape
//!   LLVM turns into straight AVX; the unpadded tail path exists only for
//!   the reference loops and small tests.

use crate::linalg::simd::{dot_lanes, dot_padded, pad_r, LANES};
use crate::linalg::Matrix;
use crate::util::bitset::DirtyRows;

/// Per-worker scratch buffers: everything the inner loops need, allocated
/// once per worker and **pooled across epochs** by the engine (paper:
/// registers + shared memory; here: heap buffers that never reallocate on
/// the epoch path).
pub struct Scratch {
    /// `v ∈ R^{pad_r(R)}` — the chain products, rank-padded (lanes past R
    /// are always `+0.0`).
    pub v: Vec<f32>,
    /// `w ∈ R^J` — the fiber-shared intermediate.
    pub w: Vec<f32>,
    /// row buffer `∈ R^J`.
    pub row: Vec<f32>,
    /// previous fiber path (for prefix-product caching).
    pub prev_path: Vec<u32>,
    /// coordinate sub-tuple buffer (COO paths: the N−1 non-update coords).
    pub sub: Vec<u32>,
    /// partial prefix products per internal level:
    /// `(N-1) × pad_r(R)` row-major.
    pub pprod: Vec<f32>,
    /// core-gradient accumulator `J×R` (core epochs only; unpadded — the
    /// accumulation is element-wise, so padding buys nothing there).
    pub grad: Matrix,
    /// Factor rows this worker touched since the last refresh. Sized
    /// lazily per mode (`ensure` is grow-only), merged into the model's
    /// per-mode dirty set at pass end — a word-OR, never an allocation on
    /// the epoch path. Deliberately not part of [`Scratch::fits`]: the
    /// bitset adapts to any mode dimension.
    pub dirty: DirtyRows,
}

impl Scratch {
    /// Buffers sized for an order-`order` model with ranks `J = j`, `R = r`
    /// (rank-direction buffers padded to the lane stride).
    pub fn new(order: usize, j: usize, r: usize) -> Scratch {
        let stride = pad_r(r);
        Scratch {
            v: vec![0.0; stride],
            w: vec![0.0; j],
            row: vec![0.0; j],
            prev_path: Vec::new(),
            sub: Vec::with_capacity(order),
            pprod: vec![0.0; (order.max(2) - 1) * stride],
            grad: Matrix::zeros(j, r),
            dirty: DirtyRows::new(),
        }
    }

    /// Whether this scratch was built for the given shape — the engine's
    /// pool check before reusing a buffer across epochs.
    pub fn fits(&self, order: usize, j: usize, r: usize) -> bool {
        let stride = pad_r(r);
        self.v.len() == stride
            && self.w.len() == j
            && self.row.len() == j
            && self.pprod.len() == (order.max(2) - 1) * stride
            && self.grad.rows() == j
            && self.grad.cols() == r
    }

    /// Invalidate the prefix cache (call when starting a new block, whose
    /// first fiber has no guaranteed relation to the previous one).
    pub fn reset_prefix(&mut self) {
        self.prev_path.clear();
    }
}

/// Target working-set bytes for one leaf-run tile — sized to stay
/// comfortably inside a typical per-core L2 (512 KiB here, conservative
/// across the x86 server parts this targets) after the fiber's hot
/// operands are charged.
const L2_TARGET_BYTES: usize = 512 * 1024;

/// Tile size (in non-zeros) from a small cost model over the ranks and
/// the SIMD lane width: each leaf non-zero streams one `u32` index and
/// one `f32` value and touches a `J`-float factor row, while the tile as
/// a whole shares the fiber's `pad_r(R)`-float chain row and `J`-float
/// `w` (charged once, as `pad_r(r) * 16` bytes of standing overhead).
/// Clamped to `[8·LANES, 65536]` so degenerate ranks neither thrash nor
/// collapse to per-nnz overhead. Pure and deterministic — the tile size
/// is a performance knob only; tiling chunks the *existing* traversal
/// order, so any value is bitwise-identical to the untiled sweep.
pub fn auto_tile_nnz(j: usize, r: usize) -> usize {
    let standing = pad_r(r) * 16;
    let per_nnz = (j * 4 + 8).max(1);
    (L2_TARGET_BYTES.saturating_sub(standing) / per_nnz).clamp(LANES * 8, 65_536)
}

/// Resolve the configured `--tile-nnz` knob: `0` = the
/// [`auto_tile_nnz`] cost model, anything else verbatim (with
/// `usize::MAX` effectively disabling tiling — one tile per leaf run).
pub fn effective_tile_nnz(cfg_tile: usize, j: usize, r: usize) -> usize {
    if cfg_tile == 0 {
        auto_tile_nnz(j, r)
    } else {
        cfg_tile
    }
}

/// `v *= row` lane-wise; `v` lanes past `row.len()` are set to `+0.0`
/// (exactly what multiplying by a rank-padded row would produce).
#[inline]
fn mul_row_into(v: &mut [f32], row: &[f32]) {
    let n = row.len().min(v.len());
    for (vi, ri) in v[..n].iter_mut().zip(&row[..n]) {
        *vi *= *ri;
    }
    for vi in &mut v[n..] {
        *vi = 0.0;
    }
}

/// `v_r = Π_k C[modes[k]][coords[k], r]` — FasterTucker's table lookup form.
/// `v` may be rank-padded; pad lanes come out `+0.0`.
#[inline]
pub fn chain_v_from_tables(
    c_tables: &[Matrix],
    modes: &[usize],
    coords: &[u32],
    v: &mut [f32],
) {
    debug_assert_eq!(modes.len(), coords.len());
    v.fill(1.0);
    for (&m, &c) in modes.iter().zip(coords.iter()) {
        mul_row_into(v, c_tables[m].row(c as usize));
    }
}

/// Prefix-cached variant: reuses partial products for the leading path
/// levels shared with the previous fiber (the CSF-tree walk of Algorithm 4:
/// upper-level `a·b` rows are only re-read when the tree branch changes).
///
/// `modes[k]`/`path[k]` are the internal levels in CSF order; `pprod` holds
/// the running product after each level at the rank-padded stride.
#[inline]
pub fn chain_v_prefix_cached(
    c_tables: &[Matrix],
    modes: &[usize],
    path: &[u32],
    scratch: &mut Scratch,
) {
    let stride = scratch.v.len();
    let plen = modes.len();
    debug_assert_eq!(path.len(), plen);
    // longest shared prefix with previous fiber
    let shared = if scratch.prev_path.len() == plen {
        scratch
            .prev_path
            .iter()
            .zip(path.iter())
            .take_while(|(a, b)| a == b)
            .count()
    } else {
        0
    };
    for k in shared..plen {
        let crow = c_tables[modes[k]].row(path[k] as usize);
        let (lo, hi) = (k * stride, (k + 1) * stride);
        let n = crow.len().min(stride);
        if k == 0 {
            let dst = &mut scratch.pprod[lo..hi];
            dst[..n].copy_from_slice(&crow[..n]);
            dst[n..].fill(0.0);
        } else {
            // pprod[k] = pprod[k-1] * crow
            let (prev, cur) = scratch.pprod.split_at_mut(lo);
            let prev = &prev[lo - stride..];
            let cur = &mut cur[..stride];
            for i in 0..n {
                cur[i] = prev[i] * crow[i];
            }
            cur[n..].fill(0.0);
        }
    }
    scratch
        .v
        .copy_from_slice(&scratch.pprod[(plen - 1) * stride..plen * stride]);
    scratch.prev_path.clear();
    scratch.prev_path.extend_from_slice(path);
}

/// `v_r = Π_k (A[modes[k]][coords[k]] · B[modes[k]][:,r])` — FastTucker's
/// on-the-fly form: `(N−1)·J·R` multiplications per non-zero (the cost the
/// paper's Theory contribution removes). Pad lanes of `v` are zeroed.
#[inline]
pub fn chain_v_on_the_fly(
    factors: &[Matrix],
    cores: &[Matrix],
    modes: &[usize],
    coords: &[u32],
    v: &mut [f32],
) {
    debug_assert_eq!(modes.len(), coords.len());
    let r = modes.first().map_or(v.len(), |&m| cores[m].cols()).min(v.len());
    v[..r].fill(1.0);
    v[r..].fill(0.0);
    for (&m, &c) in modes.iter().zip(coords.iter()) {
        let a = factors[m].row(c as usize);
        let b = &cores[m];
        let j = b.rows();
        for (rr, vr) in v[..r].iter_mut().enumerate() {
            let mut d = 0.0f32;
            for jj in 0..j {
                d += a[jj] * b.get(jj, rr);
            }
            *vr *= d;
        }
    }
}

/// `w = B v` (J×R times R) — the fiber-shared intermediate. `B` may be the
/// rank-padded copy (cols == `v.len()`, the remainder-free fast path) or
/// the raw `J×R` core; both produce identical bits (see module docs).
#[inline]
pub fn fiber_w(b: &Matrix, v: &[f32], w: &mut [f32]) {
    debug_assert!(v.len() >= b.cols(), "v must cover every core column");
    debug_assert_eq!(b.rows(), w.len());
    let bcols = b.cols();
    if bcols == v.len() && bcols % LANES == 0 {
        // rank-padded fast path: whole rows stream as 8-lane FMA groups —
        // the same `dot_padded` kernel the serving scorer runs on its
        // published rank-padded `C` rows
        for (wj, brow) in w.iter_mut().zip(b.data().chunks_exact(bcols)) {
            *wj = dot_padded(brow, v);
        }
    } else {
        // unpadded tail path: zero-extend both sides in registers — the
        // identical lane values, hence the identical reduction
        for (wj, brow) in w.iter_mut().zip(b.data().chunks_exact(bcols)) {
            *wj = dot_lanes(brow, v);
        }
    }
}

/// Accumulate the core gradient for one non-zero:
/// `G[:,r] += e·v_r·a` for all r (eq. 11, sign folded so the caller applies
/// `B += γ(G/|Ω| − λB)`). Element-wise (no reduction), so any rank padding
/// of `v` beyond `grad.cols()` is simply ignored.
#[inline]
pub fn accumulate_core_grad(grad: &mut Matrix, e: f32, v: &[f32], a: &[f32]) {
    let r = grad.cols();
    debug_assert!(v.len() >= r);
    debug_assert_eq!(a.len(), grad.rows());
    let gdata = grad.data_mut();
    for (grow, &aj) in gdata.chunks_exact_mut(r).zip(a.iter()) {
        let ea = e * aj;
        for (g, &vr) in grow.iter_mut().zip(v.iter()) {
            *g += ea * vr;
        }
    }
}

/// Apply the accumulated core gradient:
/// `B ← B + γ_B (G/|Ω| − λ_B B)`.
pub fn apply_core_grad(b: &mut Matrix, grad: &Matrix, nnz: usize, lr: f32, lambda: f32) {
    debug_assert_eq!(b.rows(), grad.rows());
    debug_assert_eq!(b.cols(), grad.cols());
    let inv = 1.0 / nnz.max(1) as f32;
    for (bv, gv) in b.data_mut().iter_mut().zip(grad.data().iter()) {
        *bv += lr * (gv * inv - lambda * *bv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    type Toy = (Vec<Matrix>, Vec<Matrix>, Vec<Matrix>);

    fn toy(seed: u64, order: usize, j: usize, r: usize, dim: usize) -> Toy {
        let mut rng = Rng::new(seed);
        let factors: Vec<Matrix> =
            (0..order).map(|_| Matrix::uniform(dim, j, -1.0, 1.0, &mut rng)).collect();
        let cores: Vec<Matrix> =
            (0..order).map(|_| Matrix::uniform(j, r, -1.0, 1.0, &mut rng)).collect();
        let c_tables: Vec<Matrix> =
            factors.iter().zip(cores.iter()).map(|(a, b)| a.matmul(b)).collect();
        (factors, cores, c_tables)
    }

    #[test]
    fn table_and_on_the_fly_chains_agree() {
        let (factors, cores, c_tables) = toy(1, 4, 6, 5, 10);
        let modes = [0usize, 2, 3];
        let coords = [3u32, 7, 1];
        let mut v1 = vec![0.0; pad_r(5)];
        let mut v2 = vec![0.0; pad_r(5)];
        chain_v_from_tables(&c_tables, &modes, &coords, &mut v1);
        chain_v_on_the_fly(&factors, &cores, &modes, &coords, &mut v2);
        for (a, b) in v1.iter().take(5).zip(v2.iter()) {
            assert!((a - b).abs() < 1e-4, "{v1:?} vs {v2:?}");
        }
        assert!(v1[5..].iter().all(|&x| x == 0.0), "pad lanes must be zero");
        assert!(v2[5..].iter().all(|&x| x == 0.0), "pad lanes must be zero");
    }

    #[test]
    fn prefix_cached_matches_uncached() {
        let (_, _, c_tables) = toy(2, 4, 6, 5, 10);
        let modes = [1usize, 2, 3];
        let mut scratch = Scratch::new(4, 6, 5);
        let paths: [[u32; 3]; 4] = [[2, 3, 4], [2, 3, 5], [2, 6, 0], [9, 0, 0]];
        for path in paths {
            chain_v_prefix_cached(&c_tables, &modes, &path, &mut scratch);
            let mut expect = vec![0.0; pad_r(5)];
            chain_v_from_tables(&c_tables, &modes, &path, &mut expect);
            for (a, b) in scratch.v.iter().zip(expect.iter()) {
                assert!((a - b).abs() < 1e-5, "path {path:?}");
            }
        }
    }

    #[test]
    fn prefix_cache_reset_is_safe() {
        let (_, _, c_tables) = toy(3, 3, 4, 4, 8);
        let modes = [0usize, 1];
        let mut scratch = Scratch::new(3, 4, 4);
        chain_v_prefix_cached(&c_tables, &modes, &[1, 2], &mut scratch);
        scratch.reset_prefix();
        chain_v_prefix_cached(&c_tables, &modes, &[1, 3], &mut scratch);
        let mut expect = vec![0.0; pad_r(4)];
        chain_v_from_tables(&c_tables, &modes, &[1, 3], &mut expect);
        for (a, b) in scratch.v.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    /// The bit-parity contract the engine's padded copies rest on: each
    /// kernel fed a rank-padded matrix must return *exactly* the bits it
    /// returns for the unpadded original.
    #[test]
    fn padded_and_unpadded_inputs_are_bitwise_identical() {
        let (_, cores, c_tables) = toy(9, 4, 6, 5, 12);
        let padded_tables: Vec<Matrix> = c_tables.iter().map(Matrix::rank_padded).collect();
        let padded_core = cores[0].rank_padded();
        let modes = [1usize, 2, 3];
        let coords = [5u32, 0, 11];

        let mut v_plain = vec![0.0f32; pad_r(5)];
        let mut v_padded = vec![0.0f32; pad_r(5)];
        chain_v_from_tables(&c_tables, &modes, &coords, &mut v_plain);
        chain_v_from_tables(&padded_tables, &modes, &coords, &mut v_padded);
        assert_eq!(v_plain, v_padded);

        let mut s_plain = Scratch::new(4, 6, 5);
        let mut s_padded = Scratch::new(4, 6, 5);
        for path in [[5u32, 0, 11], [5, 0, 3], [2, 1, 0]] {
            chain_v_prefix_cached(&c_tables, &modes, &path, &mut s_plain);
            chain_v_prefix_cached(&padded_tables, &modes, &path, &mut s_padded);
            assert_eq!(s_plain.v, s_padded.v, "path {path:?}");
        }

        let mut w_plain = vec![0.0f32; 6];
        let mut w_padded = vec![0.0f32; 6];
        fiber_w(&cores[0], &v_plain, &mut w_plain);
        fiber_w(&padded_core, &v_padded, &mut w_padded);
        assert_eq!(w_plain, w_padded);
    }

    #[test]
    fn fiber_w_is_matvec() {
        let b = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let v = [1.0f32, 0.5, 2.0];
        let mut w = [0.0f32; 2];
        fiber_w(&b, &v, &mut w);
        assert_eq!(w, [1.0 + 1.0 + 6.0, 4.0 + 2.5 + 12.0]);
    }

    #[test]
    fn tile_cost_model_is_deterministic_and_clamped() {
        // pure function: same inputs, same tile
        assert_eq!(auto_tile_nnz(32, 32), auto_tile_nnz(32, 32));
        // realistic ranks land strictly inside the clamp bounds
        let t = auto_tile_nnz(32, 32);
        assert!(t > LANES * 8 && t < 65_536, "tile {t}");
        // bigger J → smaller tile (more bytes per nnz)
        assert!(auto_tile_nnz(256, 32) < auto_tile_nnz(16, 32));
        // degenerate ranks clamp instead of exploding or vanishing
        assert!(auto_tile_nnz(0, 1) <= 65_536);
        assert_eq!(auto_tile_nnz(1 << 20, 1), LANES * 8);
        // a rank so huge the standing charge exceeds the budget still
        // yields the floor, not zero
        assert_eq!(auto_tile_nnz(8, 1 << 20), LANES * 8);
        // knob resolution: 0 = auto, explicit values verbatim
        assert_eq!(effective_tile_nnz(0, 32, 32), auto_tile_nnz(32, 32));
        assert_eq!(effective_tile_nnz(777, 32, 32), 777);
        assert_eq!(effective_tile_nnz(usize::MAX, 32, 32), usize::MAX);
    }

    #[test]
    fn scratch_fits_checks_every_dimension() {
        let s = Scratch::new(3, 6, 5);
        assert!(s.fits(3, 6, 5));
        assert!(!s.fits(3, 6, 4));
        assert!(!s.fits(3, 7, 5));
        assert!(!s.fits(4, 6, 5));
        // rank padding: 5 and 6 share a stride but grad distinguishes them
        assert!(!s.fits(3, 6, 6));
    }

    /// The factor gradient must match a finite-difference of the loss
    /// `f(a) = (x − a·w)² + λ‖a‖²` — the definitive correctness check.
    #[test]
    fn factor_step_matches_finite_difference() {
        let j = 5;
        let mut rng = Rng::new(7);
        let a: Vec<f32> = (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let x = 1.7f32;
        let lambda = 0.3f32;
        let loss = |a: &[f32]| -> f64 {
            let xhat: f32 = a.iter().zip(w.iter()).map(|(ai, wi)| ai * wi).sum();
            let e = (x - xhat) as f64;
            e * e + lambda as f64 * a.iter().map(|&ai| (ai * ai) as f64).sum::<f64>()
        };
        // analytic gradient of the loss: −2e·w + 2λa; our step uses e·w − λa
        // (the ½-scaled negative gradient, standard for SGD implementations)
        let xhat: f32 = a.iter().zip(w.iter()).map(|(ai, wi)| ai * wi).sum();
        let e = x - xhat;
        for k in 0..j {
            let step_dir = e * w[k] - lambda * a[k];
            let h = 1e-3f32;
            let mut ap = a.clone();
            ap[k] += h;
            let mut am = a.clone();
            am[k] -= h;
            let fd = -((loss(&ap) - loss(&am)) / (2.0 * h as f64)) / 2.0;
            assert!(
                (fd - step_dir as f64).abs() < 1e-2,
                "k={k}: fd {fd} vs step {step_dir}"
            );
        }
    }

    /// Core gradient ↔ finite difference of `f(b_r) = (x − x̂)² + λ‖b_r‖²`
    /// where `x̂ = Σ_r (a·b_r)·v_r` and v depends on the *other* modes only.
    #[test]
    fn core_step_matches_finite_difference() {
        let (j, r) = (4, 3);
        let mut rng = Rng::new(8);
        let a: Vec<f32> = (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let v: Vec<f32> = (0..r).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let mut b = Matrix::uniform(j, r, -1.0, 1.0, &mut rng);
        let x = 0.9f32;
        let predict = |b: &Matrix| -> f32 {
            let mut acc = 0.0;
            for rr in 0..r {
                let mut d = 0.0;
                for jj in 0..j {
                    d += a[jj] * b.get(jj, rr);
                }
                acc += d * v[rr];
            }
            acc
        };
        let e = x - predict(&b);
        let mut grad = Matrix::zeros(j, r);
        accumulate_core_grad(&mut grad, e, &v, &a);
        // finite difference of ½(x−x̂)² wrt b[jj,rr] should equal −grad
        for jj in 0..j {
            for rr in 0..r {
                let h = 1e-3f32;
                let orig = b.get(jj, rr);
                b.set(jj, rr, orig + h);
                let lp = {
                    let e = (x - predict(&b)) as f64;
                    0.5 * e * e
                };
                b.set(jj, rr, orig - h);
                let lm = {
                    let e = (x - predict(&b)) as f64;
                    0.5 * e * e
                };
                b.set(jj, rr, orig);
                let fd = -(lp - lm) / (2.0 * h as f64);
                assert!(
                    (fd - grad.get(jj, rr) as f64).abs() < 5e-2,
                    "({jj},{rr}): fd {fd} vs {}",
                    grad.get(jj, rr)
                );
            }
        }
    }

    #[test]
    fn apply_core_grad_formula() {
        let mut b = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let g = Matrix::from_vec(1, 2, vec![10.0, 20.0]);
        apply_core_grad(&mut b, &g, 10, 0.1, 0.5);
        // b += 0.1*(g/10 − 0.5*b)
        assert!((b.get(0, 0) - (1.0 + 0.1 * (1.0 - 0.5))).abs() < 1e-6);
        assert!((b.get(0, 1) - (2.0 + 0.1 * (2.0 - 1.0))).abs() < 1e-6);
    }
}
