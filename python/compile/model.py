"""L2 model: the FastTucker computation graph, composed from the L1 kernels.

These jitted functions are what ``aot.py`` lowers to HLO text. Each one
calls the Pallas kernels (which lower inline under ``interpret=True`` into
plain HLO ops) so the exported artifact contains the whole fused graph.

The L2 compositions mirror the paper's two update modules:

* :func:`predict_and_error` — prediction + residual for a batch (the shared
  front half of both modules).
* :func:`core_update` — full-batch core-matrix step: errors → scaled rows →
  gradient matmul → regularized SGD application (eq. 9 + 11). One HLO.
* :func:`c_refresh` — Algorithm 3's reusable-table rebuild.
"""

import jax
import jax.numpy as jnp

from .kernels import core_grad, precompute_c, predict_batch


def c_refresh(a, b):
    """C^(n) = A^(n) B^(n) (Algorithm 3)."""
    return precompute_c(a, b)


def predict_and_error(values, *crows):
    """Return (x̂, e = x − x̂) for a batch gathered from the C tables."""
    xhat = predict_batch(*crows)
    return xhat, values - xhat


def core_update(b, values, a_rows, v, lr, lam, inv_nnz):
    """One core-matrix step over a batch (paper eq. 9 + 11).

    Args:
      b:      (J, R) current core matrix B^(n).
      values: (B,) observed entries.
      a_rows: (B, J) gathered factor rows a_{i_n}.
      v:      (B, R) chain products Π_{n'≠n} C^(n')[i_{n'}, :].
      lr, lam, inv_nnz: scalars γ_B, λ_B, 1/|Ω|.

    Returns the updated B^(n).
    """
    # x̂ = (a·B)·v per element: reuse the predict kernel on (a@B, v) pairs —
    # a@B is exactly the element's own C-row contribution.
    own = precompute_c(a_rows, b)  # (B, R)
    xhat = predict_batch(own, v)  # Σ_r own·v
    e = values - xhat
    ea = a_rows * e[:, None]
    g = core_grad(ea, v)  # (J, R)
    return b + lr * (g * inv_nnz - lam * b)


def batch_rmse(values, *crows):
    """Batch RMSE from gathered C rows (the evaluation artifact)."""
    _, err = predict_and_error(values, *crows)
    return jnp.sqrt(jnp.mean(err * err))
