"""Pure-jnp oracles for the L1 kernels — the correctness ground truth.

Every Pallas kernel is asserted allclose against these in
``python/tests/test_kernels.py`` (hypothesis sweeps shapes), and the same
formulas are re-implemented in Rust (`rust/src/algo/grad.rs`), giving a
three-way agreement check: Pallas ⇔ jnp ⇔ Rust.
"""

import jax.numpy as jnp


def precompute_c_ref(a, b):
    """C = A @ B."""
    return jnp.asarray(a, jnp.float32) @ jnp.asarray(b, jnp.float32)


def predict_batch_ref(*crows):
    """x̂[b] = Σ_r Π_n crows[n][b, r]."""
    p = jnp.ones_like(crows[0])
    for c in crows:
        p = p * c
    return jnp.sum(p, axis=1)


def core_grad_ref(ea, v):
    """G = eaᵀ @ v."""
    return jnp.asarray(ea, jnp.float32).T @ jnp.asarray(v, jnp.float32)


def fastucker_predict_element_ref(a_rows, b_mats):
    """Scalar x̂ = Σ_r Π_n (a^(n) · b^(n)_{:,r}) — eq. 12 for one element."""
    r = b_mats[0].shape[1]
    acc = jnp.ones((r,), jnp.float32)
    for a, b in zip(a_rows, b_mats):
        acc = acc * (jnp.asarray(a, jnp.float32) @ jnp.asarray(b, jnp.float32))
    return jnp.sum(acc)
