"""L1 kernel: ``C = A @ B`` — the reusable-intermediate table refresh.

TPU mapping (DESIGN.md §Hardware-Adaptation): ``B`` (J×R ≤ 32×32 f32 =
4 KiB) is small enough to stay fully resident in VMEM for every grid step,
while ``A`` streams through in row tiles of ``TILE_I`` — the BlockSpec
pipeline double-buffers the HBM→VMEM copies. The J-contraction hits the MXU
as a single (TILE_I×J)@(J×R) matmul per step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. 256×32 f32 = 32 KiB per A tile — comfortably inside VMEM
# alongside B and the output tile.
TILE_I = 256


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def precompute_c(a: jax.Array, b: jax.Array) -> jax.Array:
    """``C[i, r] = Σ_j A[i, j] · B[j, r]`` via a row-tiled Pallas kernel.

    ``A`` must have a row count divisible by the tile height (the AOT
    harness pads to buckets; direct callers can pass any multiple of
    :data:`TILE_I`, or small matrices which fall back to a single tile).
    """
    i, j = a.shape
    j2, r = b.shape
    assert j == j2, f"contraction mismatch: {a.shape} @ {b.shape}"
    tile = TILE_I if i % TILE_I == 0 else i
    grid = (i // tile,)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((i, r), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, j), lambda k: (k, 0)),
            pl.BlockSpec((j, r), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, r), lambda k: (k, 0)),
        interpret=True,
    )(a, b)
