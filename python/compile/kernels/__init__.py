"""L1 Pallas kernels for the FasterTucker dense building blocks.

Three kernels cover the paper's dense hot-spots (everything else is sparse
bookkeeping that lives in the Rust coordinator):

* :mod:`.precompute_c` — ``C = A @ B``, the *reusable intermediate* tables
  (paper Algorithm 3).
* :mod:`.predict` — batched chain-product prediction
  ``x̂_b = Σ_r Π_n Crows[n][b, r]`` (paper eq. 12 applied to a batch).
* :mod:`.core_grad` — ``G = (e·A)ᵀ V``, the accumulated core-matrix gradient
  (paper eq. 11 over a batch).

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness path and the tiling
structure (BlockSpecs) documents the intended TPU schedule. See
DESIGN.md §Hardware-Adaptation for the CUDA→TPU mapping.
"""

from .precompute_c import precompute_c
from .predict import predict_batch
from .core_grad import core_grad

__all__ = ["precompute_c", "predict_batch", "core_grad"]
