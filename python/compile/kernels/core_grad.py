"""L1 kernel: accumulated core-matrix gradient ``G = EAᵀ V``.

Paper eq. 11 for a batch: each non-zero contributes ``e_b · a_b ⊗ v_b`` to
the gradient of ``B^(n)``; over a batch this is the matmul
``G[j, r] = Σ_b (e_b·a_b[j]) · v_b[r]``. The Rust side pre-scales the factor
rows by the error (``ea = diag(e)·A``), so the kernel is a pure
``(J×B)@(B×R)`` contraction.

TPU mapping: the batch dimension is tiled and *accumulated across grid
steps* into the same (J, R) output block — the canonical Pallas reduction
pattern (init on step 0, `+=` after), which pipelines HBM reads of the
batch tiles while the 32×32 accumulator stays pinned in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 1024


def _core_grad_kernel(ea_ref, v_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    partial = jnp.dot(
        ea_ref[...].T, v_ref[...], preferred_element_type=jnp.float32
    )
    o_ref[...] += partial


def core_grad(ea: jax.Array, v: jax.Array) -> jax.Array:
    """``G = eaᵀ @ v`` with batch-tiled accumulation.

    ``ea``: (B, J) error-scaled factor rows; ``v``: (B, R) chain products.
    """
    b, j = ea.shape
    b2, r = v.shape
    assert b == b2, f"batch mismatch: {ea.shape} vs {v.shape}"
    tile = TILE_B if b % TILE_B == 0 else b
    grid = (b // tile,)
    return pl.pallas_call(
        _core_grad_kernel,
        out_shape=jax.ShapeDtypeStruct((j, r), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, j), lambda k: (k, 0)),
            pl.BlockSpec((tile, r), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((j, r), lambda k: (0, 0)),
        interpret=True,
    )(ea, v)
