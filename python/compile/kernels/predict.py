"""L1 kernel: batched chain-product prediction.

``x̂[b] = Σ_r Π_n crows[n][b, r]`` — the element-prediction rule of
FastTucker (paper eq. 12): the chain of scalar products over the C tables,
summed over the R rank-one components.

TPU mapping: each grid step holds N tiles of shape (TILE_B, R) in VMEM
(N ≤ 10, R ≤ 32 → ≤ 1.3 MiB at TILE_B=1024); the mode product is a
vectorized elementwise multiply on the VPU and the R-reduction a lane sum.
The gather of C rows happens on the Rust side (sparse indices never enter
the kernel), so the kernel body is fully dense — the same split the paper's
warp shuffle dot-products achieve.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 1024


def _make_kernel(n_modes: int):
    def kernel(*refs):
        o_ref = refs[-1]
        p = refs[0][...]
        for k in range(1, n_modes):
            p = p * refs[k][...]
        o_ref[...] = jnp.sum(p, axis=1)

    return kernel


def predict_batch(*crows: jax.Array) -> jax.Array:
    """Batched prediction from per-mode C-table rows (each ``(B, R)``)."""
    n = len(crows)
    assert n >= 2, "need at least two modes"
    b, r = crows[0].shape
    for c in crows:
        assert c.shape == (b, r), "ragged crows inputs"
    tile = TILE_B if b % TILE_B == 0 else b
    grid = (b // tile,)
    return pl.pallas_call(
        _make_kernel(n),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, r), lambda k: (k, 0)) for _ in range(n)],
        out_specs=pl.BlockSpec((tile,), lambda k: (k,)),
        interpret=True,
    )(*crows)
