"""Build-time compile path (L2 JAX model + L1 Pallas kernels + AOT export).

Nothing in this package runs on the request path: `make artifacts` lowers
every computation to HLO text under `artifacts/`, and the Rust coordinator
executes them through the PJRT C API (`rust/src/runtime/`).
"""
