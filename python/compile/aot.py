"""AOT export: lower the L2/L1 computations to HLO text + manifest.json.

Interchange format is HLO **text**, not serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are shape-specialized; the Rust runtime pads inputs to the nearest
bucket. The default set covers J/R ∈ {8, 16, 32}, matmul row buckets up to
256 Ki rows, and predict for orders 3–6.

Usage::

    python -m compile.aot --out-dir ../artifacts [--quick]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

MANIFEST_VERSION = 1

# matmul row buckets (padded I_n). 1024 covers the tests/tiny runs; the top
# bucket covers the netflix-like user mode at bench scale.
MATMUL_BUCKETS = [1024, 16384, 65536, 262144]
RANKS = [8, 16, 32]
PREDICT_ORDERS = [3, 4, 5, 6]
BATCH = 8192


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side can uniformly unwrap a 1-tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_matmul(i: int, j: int, r: int) -> str:
    fn = jax.jit(lambda a, b: (model.c_refresh(a, b),))
    a = jax.ShapeDtypeStruct((i, j), jnp.float32)
    b = jax.ShapeDtypeStruct((j, r), jnp.float32)
    return to_hlo_text(fn.lower(a, b))


def lower_predict(n: int, b: int, r: int) -> str:
    fn = jax.jit(lambda *crows: (model.predict_and_error(jnp.zeros((b,)), *crows)[0],))
    specs = [jax.ShapeDtypeStruct((b, r), jnp.float32) for _ in range(n)]
    return to_hlo_text(fn.lower(*specs))


def lower_core_grad(b: int, j: int, r: int) -> str:
    from .kernels import core_grad

    fn = jax.jit(lambda ea, v: (core_grad(ea, v),))
    ea = jax.ShapeDtypeStruct((b, j), jnp.float32)
    v = jax.ShapeDtypeStruct((b, r), jnp.float32)
    return to_hlo_text(fn.lower(ea, v))


def build_entries(quick: bool):
    """The artifact catalogue: (name, op, params, lower-thunk)."""
    entries = []
    buckets = MATMUL_BUCKETS[:2] if quick else MATMUL_BUCKETS
    ranks = [32] if quick else RANKS
    orders = [3] if quick else PREDICT_ORDERS
    for jr in ranks:
        for i in buckets:
            entries.append(
                (
                    f"matmul_i{i}_j{jr}_r{jr}",
                    "matmul",
                    {"i": i, "j": jr, "r": jr},
                    lambda i=i, j=jr, r=jr: lower_matmul(i, j, r),
                )
            )
        for n in orders:
            entries.append(
                (
                    f"predict_n{n}_b{BATCH}_r{jr}",
                    "predict",
                    {"n": n, "b": BATCH, "r": jr},
                    lambda n=n, r=jr: lower_predict(n, BATCH, r),
                )
            )
        entries.append(
            (
                f"core_grad_b{BATCH}_j{jr}_r{jr}",
                "core_grad",
                {"b": BATCH, "j": jr, "r": jr},
                lambda j=jr, r=jr: lower_core_grad(BATCH, j, r),
            )
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="small artifact set (tests / CI)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": MANIFEST_VERSION, "entries": []}
    for name, op, params, thunk in build_entries(args.quick):
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        text = thunk()
        assert "HloModule" in text, f"{name}: unexpected lowering output"
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {"name": name, "op": op, "file": fname, "params": params}
        )
        print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(
        f"wrote manifest with {len(manifest['entries'])} entries to "
        f"{args.out_dir}/manifest.json"
    )


if __name__ == "__main__":
    main()
