"""AOT pipeline tests: lowering produces parseable HLO text + a manifest the
Rust runtime's schema accepts."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from compile import aot


def test_to_hlo_text_contains_module():
    text = aot.lower_matmul(64, 8, 8)
    assert "HloModule" in text
    # HLO text must mention the padded shapes
    assert "f32[64,8]" in text
    assert "f32[8,8]" in text


def test_predict_lowering_has_all_inputs():
    text = aot.lower_predict(3, 128, 8)
    assert text.count("f32[128,8]") >= 3


def test_core_grad_lowering_output_shape():
    text = aot.lower_core_grad(1024, 16, 8)
    assert "f32[16,8]" in text


def test_quick_catalogue_covers_all_ops():
    entries = aot.build_entries(quick=True)
    ops = {op for _, op, _, _ in entries}
    assert ops == {"matmul", "predict", "core_grad"}


def test_full_catalogue_shapes():
    entries = aot.build_entries(quick=False)
    names = [n for n, _, _, _ in entries]
    assert len(names) == len(set(names)), "duplicate artifact names"
    # every rank gets matmul buckets, predict orders 3..6, one core_grad
    matmuls = [p for _, op, p, _ in entries if op == "matmul"]
    assert {p["j"] for p in matmuls} == {8, 16, 32}
    predicts = [p for _, op, p, _ in entries if op == "predict"]
    assert {p["n"] for p in predicts} == {3, 4, 5, 6}


@pytest.mark.slow
def test_aot_main_quick_writes_manifest(tmp_path):
    """End-to-end: `python -m compile.aot --quick` produces a valid bundle."""
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        check=True,
        cwd=Path(__file__).resolve().parents[1],
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["entries"]) >= 4
    for e in manifest["entries"]:
        f = out / e["file"]
        assert f.exists(), e
        assert "HloModule" in f.read_text()[:200]
