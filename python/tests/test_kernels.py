"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; fixed `@example`s pin the AOT
shapes actually exported by aot.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import example, given, settings
from hypothesis import strategies as st

from compile.kernels import core_grad, precompute_c, predict_batch
from compile.kernels.ref import (
    core_grad_ref,
    fastucker_predict_element_ref,
    precompute_c_ref,
    predict_batch_ref,
)

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape), jnp.float32)


@given(
    i=st.integers(1, 64).map(lambda x: x * 8),
    j=st.sampled_from([1, 3, 8, 16, 32]),
    r=st.sampled_from([1, 4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@example(i=1024, j=32, r=32, seed=0)
@example(i=256, j=8, r=8, seed=1)
@settings(**SETTINGS)
def test_precompute_c_matches_ref(i, j, r, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, i, j), rand(rng, j, r)
    got = precompute_c(a, b)
    want = precompute_c_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    n=st.integers(2, 6),
    b=st.sampled_from([1, 7, 64, 1024, 2048]),
    r=st.sampled_from([1, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@example(n=3, b=8192, r=32, seed=0)
@settings(**SETTINGS)
def test_predict_batch_matches_ref(n, b, r, seed):
    rng = np.random.default_rng(seed)
    crows = [rand(rng, b, r) for _ in range(n)]
    got = predict_batch(*crows)
    want = predict_batch_ref(*crows)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(
    b=st.sampled_from([1, 13, 512, 1024, 4096]),
    j=st.sampled_from([1, 8, 32]),
    r=st.sampled_from([1, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@example(b=8192, j=32, r=32, seed=0)
@settings(**SETTINGS)
def test_core_grad_matches_ref(b, j, r, seed):
    rng = np.random.default_rng(seed)
    ea, v = rand(rng, b, j), rand(rng, b, r)
    got = core_grad(ea, v)
    want = core_grad_ref(ea, v)
    # accumulation across grid steps reorders sums slightly
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_core_grad_accumulates_across_tiles():
    """Multi-tile batches must accumulate, not overwrite (B > TILE_B)."""
    rng = np.random.default_rng(3)
    b = 4096  # 4 grid steps at TILE_B=1024
    ea, v = rand(rng, b, 8), rand(rng, b, 8)
    got = core_grad(ea, v)
    want = core_grad_ref(ea, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_predict_batch_rejects_single_mode():
    with pytest.raises(AssertionError):
        predict_batch(jnp.zeros((4, 2)))


def test_predict_matches_elementwise_oracle():
    """predict over gathered C rows == the per-element eq. 12 oracle."""
    rng = np.random.default_rng(7)
    n, j, r = 3, 8, 4
    a_rows = [rand(rng, j) for _ in range(n)]
    b_mats = [rand(rng, j, r) for _ in range(n)]
    crows = [jnp.reshape(a @ b, (1, r)) for a, b in zip(a_rows, b_mats)]
    got = predict_batch(*crows)[0]
    want = fastucker_predict_element_ref(a_rows, b_mats)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(
    i=st.sampled_from([8, 256]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_precompute_zero_b_gives_zero_c(i, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, i, 8)
    b = jnp.zeros((8, 4), jnp.float32)
    np.testing.assert_array_equal(precompute_c(a, b), jnp.zeros((i, 4)))


def test_kernels_handle_f32_extremes():
    """Large-magnitude inputs must not overflow in the kernels when the
    reference doesn't."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.uniform(-1e3, 1e3, size=(64, 8)), jnp.float32)
    b = jnp.asarray(rng.uniform(-1e3, 1e3, size=(8, 8)), jnp.float32)
    np.testing.assert_allclose(
        precompute_c(a, b), precompute_c_ref(a, b), rtol=1e-4
    )
