"""L2 model composition tests: the jitted update graphs vs hand-built math."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=15, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.uniform(-0.5, 0.5, size=shape), jnp.float32)


@given(
    b=st.sampled_from([4, 64, 1024]),
    r=st.sampled_from([4, 32]),
    n=st.integers(2, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_predict_and_error(b, r, n, seed):
    rng = np.random.default_rng(seed)
    crows = [rand(rng, b, r) for _ in range(n)]
    values = rand(rng, b)
    xhat, err = model.predict_and_error(values, *crows)
    want = np.prod(np.stack(crows), axis=0).sum(axis=1)
    np.testing.assert_allclose(xhat, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(err, values - want, rtol=1e-4, atol=1e-5)


@given(
    b=st.sampled_from([8, 256]),
    j=st.sampled_from([4, 8]),
    r=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_core_update_matches_manual(b, j, r, seed):
    rng = np.random.default_rng(seed)
    bmat = rand(rng, j, r)
    values = rand(rng, b)
    a_rows = rand(rng, b, j)
    v = rand(rng, b, r)
    lr, lam, inv = 0.01, 0.1, 1.0 / b

    got = model.core_update(bmat, values, a_rows, v, lr, lam, inv)

    # manual: x̂ = Σ_r (a·B)_r v_r ; e = x − x̂ ; G = (e·a)ᵀ v
    own = np.asarray(a_rows) @ np.asarray(bmat)
    xhat = (own * np.asarray(v)).sum(axis=1)
    e = np.asarray(values) - xhat
    g = (np.asarray(a_rows) * e[:, None]).T @ np.asarray(v)
    want = np.asarray(bmat) + lr * (g * inv - lam * np.asarray(bmat))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_core_update_descends_loss():
    """A core step must reduce the batch squared error on average."""
    rng = np.random.default_rng(5)
    b, j, r = 512, 8, 8
    bmat = rand(rng, j, r)
    a_rows = rand(rng, b, j)
    v = rand(rng, b, r)
    # target values generated from a "true" core so learning is possible
    btrue = rand(rng, j, r)
    own_true = np.asarray(a_rows) @ np.asarray(btrue)
    values = jnp.asarray((own_true * np.asarray(v)).sum(axis=1))

    def sq_loss(bm):
        own = np.asarray(a_rows) @ np.asarray(bm)
        xhat = (own * np.asarray(v)).sum(axis=1)
        return float(((np.asarray(values) - xhat) ** 2).mean())

    before = sq_loss(bmat)
    bnew = bmat
    for _ in range(60):
        bnew = model.core_update(bnew, values, a_rows, v, 1.0, 0.0, 1.0 / b)
    after = sq_loss(bnew)
    assert after < before * 0.7, f"loss {before} -> {after}"


def test_batch_rmse_zero_for_exact():
    rng = np.random.default_rng(9)
    crows = [rand(rng, 32, 4) for _ in range(3)]
    values = jnp.sum(crows[0] * crows[1] * crows[2], axis=1)
    assert float(model.batch_rmse(values, *crows)) < 1e-6


def test_c_refresh_is_matmul():
    rng = np.random.default_rng(13)
    a, b = rand(rng, 128, 8), rand(rng, 8, 16)
    np.testing.assert_allclose(
        model.c_refresh(a, b), np.asarray(a) @ np.asarray(b), rtol=1e-5, atol=1e-5
    )
